//! Packed-weight preparation and popcount primitives shared by the fast
//! inference engines ([`crate::nn::opt`], [`crate::nn::bitplane`]).
//!
//! The golden model expands every packed weight word back into ±1 `i32`s
//! before use; the fast paths keep rows packed. [`PackedLayer`] owns a
//! tail-masked copy of one layer's weight words so kernels can walk set
//! bits word-at-a-time without per-bit range tracking, and [`plus_sum`]
//! is the shared Σ₊ walk behind the add/sub sign identity:
//!
//! ```text
//! Σ_k w_k·x_k  =  Σ₊ − Σ₋  =  2·Σ₊ − Σ        (w_k ∈ {−1, +1})
//! ```
//!
//! so one window/feature sum Σ is computed once and reused by every
//! output channel, and only the set bits of each packed row are visited.
//!
//! The bit-plane half ([`pack_planes`], [`plane_popcounts`],
//! [`bitplane_dot`]) realizes the same identity per activation bit:
//! activations transpose into 8 packed planes and every dot product
//! becomes word-wide AND+popcount — the software shape of the FINN/
//! LUTNet XNOR-popcount datapath.
//!
//! The kernels here ([`plus_sum`], [`plane_popcounts`],
//! [`bitplane_dot`]) are the **scalar reference tier** of the
//! [`crate::nn::simd::Kernels`] dispatch table: deliberately simple,
//! never vectorized, the baseline every wider tier must match bit for
//! bit (and the denominator of the `scalar_vs_simd` bench rows).

use crate::model::weights::LayerParams;
use crate::util::TinError;
use crate::Result;

/// Largest legal requant shift. `quant_scalar` computes
/// `1 << (shift - 1)` and `>> shift` on `i32`, so any shift >= 32 from a
/// weight file is hostile input (panic in debug builds, shift-overflow
/// wrap in release).
pub const MAX_SHIFT: u8 = 31;

/// Validate one layer's parameters against the structural invariants
/// every consumer (golden model, fast path, overlay lowering) assumes.
pub fn validate_params(p: &LayerParams) -> Result<()> {
    if p.shift > MAX_SHIFT {
        return Err(TinError::Format(format!(
            "layer shift {} out of range (max {MAX_SHIFT})",
            p.shift
        )));
    }
    if p.bias.len() != p.n_out {
        return Err(TinError::Format(format!(
            "bias len {} != n_out {}",
            p.bias.len(),
            p.n_out
        )));
    }
    if p.words.len() != p.n_out * p.kw() {
        return Err(TinError::Format(format!(
            "weight words {} != n_out {} x kw {}",
            p.words.len(),
            p.n_out,
            p.kw()
        )));
    }
    Ok(())
}

/// One weighted layer with tail-masked packed rows, ready for the
/// word-at-a-time kernels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedLayer {
    /// GEMM K (9*cin for conv, flattened features for dense/svm).
    pub k_in: usize,
    /// Output channels / neurons.
    pub n_out: usize,
    /// Words per row.
    pub kw: usize,
    /// Row-major `[n_out][kw]`; bits >= k_in in each row's last word are
    /// cleared so bit walks never index past the feature vector.
    pub words: Vec<u32>,
    pub bias: Vec<i32>,
    pub shift: u8,
}

impl PackedLayer {
    /// Prepare (validate + tail-mask) a layer for the fast path.
    pub fn prepare(p: &LayerParams) -> Result<Self> {
        validate_params(p)?;
        let kw = p.kw();
        let mut words = p.words.clone();
        let rem = p.k_in % 32;
        if rem != 0 {
            let mask = (1u32 << rem) - 1;
            for n in 0..p.n_out {
                words[n * kw + kw - 1] &= mask;
            }
        }
        Ok(PackedLayer {
            k_in: p.k_in,
            n_out: p.n_out,
            kw,
            words,
            bias: p.bias.clone(),
            shift: p.shift,
        })
    }

    /// Packed row of output channel `n`.
    #[inline]
    pub fn row(&self, n: usize) -> &[u32] {
        &self.words[n * self.kw..(n + 1) * self.kw]
    }
}

/// Σ₊ of one packed row over `vals`: the sum of `vals[k]` for every set
/// bit k. With Σ = sum(vals), the ±1 dot product is `2·Σ₊ − Σ`.
///
/// `vals.len()` must cover the row's K (tail-masked rows guarantee no
/// out-of-range bit).
#[inline]
pub fn plus_sum(row: &[u32], vals: &[i32]) -> i32 {
    let mut acc = 0i32;
    let mut base = 0usize;
    for &word in row {
        let mut w = word;
        while w != 0 {
            let j = w.trailing_zeros() as usize;
            acc += vals[base + j];
            w &= w - 1;
        }
        base += 32;
    }
    acc
}

/// Transpose u8-range activations into 8 bit-planes of packed `u32`
/// words: plane `b`, word `j`, bit `i` is bit `b` of `vals[32*j + i]`.
/// `planes` must hold exactly `8 * ⌈vals.len()/32⌉` words, laid out
/// plane-major (`planes[b*kw + j]`). Bits at positions >= `vals.len()`
/// are cleared, so AND-popcount walks against tail-masked rows never
/// see phantom activations.
///
/// **Precondition:** every value must be in `0..=255` (the numeric
/// contract's activation range). Out-of-range values are rejected in
/// debug builds and silently truncated to their low 8 bits in release —
/// callers feeding anything other than contract activations get wrong
/// answers, not an error.
///
/// This is the FINN-style datapath: with planes in hand, every ±1 dot
/// product collapses to `Σ_b 2^b · (2·popcount(row ∧ plane_b) −
/// popcount(plane_b))` — word ops instead of element-serial adds.
pub fn pack_planes(vals: &[i32], planes: &mut [u32]) {
    let kw = (vals.len() + 31) / 32;
    assert_eq!(planes.len(), 8 * kw, "planes buffer must be 8 x kw words");
    planes.fill(0);
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!((0..=255).contains(&v), "bit-plane packing needs u8-range activations");
        let j = i / 32;
        let bit = 1u32 << (i % 32);
        let mut v = (v as u32) & 0xFF;
        while v != 0 {
            let b = v.trailing_zeros() as usize;
            planes[b * kw + j] |= bit;
            v &= v - 1;
        }
    }
}

/// Per-plane popcounts of a packed plane set (`planes.len() == 8 * kw`).
/// `Σ_b 2^b · pop[b]` is the activation sum Σ of the packed window, so
/// one popcount pass replaces the per-pixel window re-sum AND feeds the
/// `2·Σ₊ − Σ` identity for every output channel.
pub fn plane_popcounts(planes: &[u32]) -> [i32; 8] {
    assert!(planes.len() % 8 == 0, "planes buffer must be 8 x kw words");
    let kw = planes.len() / 8;
    let mut out = [0i32; 8];
    for (b, slot) in out.iter_mut().enumerate() {
        let mut pop = 0i32;
        for &w in &planes[b * kw..(b + 1) * kw] {
            pop += w.count_ones() as i32;
        }
        *slot = pop;
    }
    out
}

/// ±1 dot product of one tail-masked packed row against a packed plane
/// set: `Σ_b 2^b · (2·popcount(row ∧ plane_b) − pop[b])`. `pops` must be
/// [`plane_popcounts`] of the same planes (computed once per window and
/// shared across all output channels).
#[inline]
pub fn bitplane_dot(row: &[u32], planes: &[u32], pops: &[i32; 8]) -> i32 {
    let kw = row.len();
    debug_assert_eq!(planes.len(), 8 * kw, "planes/row word-count mismatch");
    let mut acc = 0i32;
    for (b, &pop) in pops.iter().enumerate() {
        let mut pos = 0i32;
        for (&w, &p) in row.iter().zip(&planes[b * kw..(b + 1) * kw]) {
            pos += (w & p).count_ones() as i32;
        }
        acc += (2 * pos - pop) << b;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng64;

    fn layer(k_in: usize, n_out: usize, seed: u64) -> LayerParams {
        let mut rng = Rng64::new(seed);
        let kw = (k_in + 31) / 32;
        LayerParams {
            k_in,
            n_out,
            words: (0..n_out * kw).map(|_| rng.next_u32()).collect(),
            bias: (0..n_out).map(|_| rng.below(100) as i32 - 50).collect(),
            shift: (rng.below(8)) as u8,
        }
    }

    #[test]
    fn prepare_masks_tail_bits() {
        let mut p = layer(33, 2, 1);
        // force stray high bits into each row's final word
        p.words[1] |= 0xFFFF_FFF0;
        p.words[3] |= 0xFFFF_FFF0;
        let pl = PackedLayer::prepare(&p).unwrap();
        assert_eq!(pl.row(0)[1], p.words[1] & 1);
        assert_eq!(pl.row(1)[1], p.words[3] & 1);
        // full words untouched
        assert_eq!(pl.row(0)[0], p.words[0]);
    }

    #[test]
    fn prepare_keeps_aligned_rows_verbatim() {
        let p = layer(64, 3, 2);
        let pl = PackedLayer::prepare(&p).unwrap();
        assert_eq!(pl.words, p.words);
    }

    #[test]
    fn plus_sum_matches_weight_walk() {
        let p = layer(70, 4, 3);
        let pl = PackedLayer::prepare(&p).unwrap();
        let mut rng = Rng64::new(9);
        let vals: Vec<i32> = (0..70).map(|_| rng.next_u8() as i32).collect();
        let total: i32 = vals.iter().sum();
        for n in 0..4 {
            let want: i32 = (0..70).map(|k| p.weight(n, k) * vals[k]).sum();
            let got = 2 * plus_sum(pl.row(n), &vals) - total;
            assert_eq!(got, want, "row {n}");
        }
    }

    #[test]
    fn pack_planes_roundtrips_values() {
        let mut rng = Rng64::new(21);
        let vals: Vec<i32> = (0..45).map(|_| rng.next_u8() as i32).collect();
        let kw = 2;
        let mut planes = vec![0u32; 8 * kw];
        pack_planes(&vals, &mut planes);
        for (i, &v) in vals.iter().enumerate() {
            let mut got = 0i32;
            for b in 0..8 {
                got |= (((planes[b * kw + i / 32] >> (i % 32)) & 1) as i32) << b;
            }
            assert_eq!(got, v, "element {i}");
        }
        // no phantom bits past K in the tail word
        for b in 0..8 {
            assert_eq!(planes[b * kw + 1] >> (45 - 32), 0, "plane {b} tail");
        }
    }

    #[test]
    fn plane_popcounts_give_activation_sum() {
        let mut rng = Rng64::new(22);
        let vals: Vec<i32> = (0..70).map(|_| rng.next_u8() as i32).collect();
        let mut planes = vec![0u32; 8 * 3];
        pack_planes(&vals, &mut planes);
        let pops = plane_popcounts(&planes);
        let sum: i32 = (0..8).map(|b| pops[b] << b).sum();
        assert_eq!(sum, vals.iter().sum::<i32>());
    }

    #[test]
    fn bitplane_dot_matches_weight_walk() {
        let p = layer(70, 4, 23);
        let pl = PackedLayer::prepare(&p).unwrap();
        let mut rng = Rng64::new(24);
        let vals: Vec<i32> = (0..70).map(|_| rng.next_u8() as i32).collect();
        let mut planes = vec![0u32; 8 * pl.kw];
        pack_planes(&vals, &mut planes);
        let pops = plane_popcounts(&planes);
        for n in 0..4 {
            let want: i32 = (0..70).map(|k| p.weight(n, k) * vals[k]).sum();
            assert_eq!(bitplane_dot(pl.row(n), &planes, &pops), want, "row {n}");
        }
    }

    #[test]
    fn bitplane_dot_agrees_with_plus_sum_on_stray_tail_bits() {
        let mut p = layer(33, 2, 25);
        p.words[1] |= 0xFFFF_FFF0; // stray bits past K in the tail word
        p.words[3] |= 0xFFFF_FFF0;
        let pl = PackedLayer::prepare(&p).unwrap();
        let mut rng = Rng64::new(26);
        let vals: Vec<i32> = (0..33).map(|_| rng.next_u8() as i32).collect();
        let total: i32 = vals.iter().sum();
        let mut planes = vec![0u32; 8 * pl.kw];
        pack_planes(&vals, &mut planes);
        let pops = plane_popcounts(&planes);
        for n in 0..2 {
            assert_eq!(
                bitplane_dot(pl.row(n), &planes, &pops),
                2 * plus_sum(pl.row(n), &vals) - total
            );
        }
    }

    #[test]
    fn hostile_shift_rejected() {
        let mut p = layer(8, 1, 4);
        p.shift = 32;
        assert!(validate_params(&p).is_err());
        assert!(PackedLayer::prepare(&p).is_err());
        p.shift = 31;
        assert!(validate_params(&p).is_ok());
    }

    #[test]
    fn malformed_geometry_rejected() {
        let mut p = layer(8, 2, 5);
        p.bias.pop();
        assert!(validate_params(&p).is_err());
        let mut p = layer(8, 2, 6);
        p.words.pop();
        assert!(validate_params(&p).is_err());
    }
}
