//! S5: fixed-point NN library — three engines over one numeric contract.
//!
//! * [`layers`] — the **golden model**: the bit-exact, obviousness-first
//!   reference for the overlay simulator, the JAX fixed model, and the
//!   PJRT artifact. Never optimized; it is the oracle.
//! * [`opt`] — the **fast path**: blocked, bit-packed, fused inference
//!   (packed-word sign trick, scratch arena, zero per-layer
//!   allocations). Bit-exact with the golden model; `proptests` pins the
//!   two together over randomized nets.
//! * [`bitplane`] — the **popcount datapath**: activations transposed
//!   into 8 packed bit-planes, every ±1 dot product computed as
//!   `Σ_b 2^b·(2·popcount(w ∧ plane_b) − popcount(plane_b))` with
//!   per-window plane popcounts shared across all output channels.
//!   Shares stage compilation with [`opt`]; bit-exact with the golden
//!   model under the same differential-proptest contract.
//! * [`pack`] — packed-weight preparation and the bit-plane/popcount
//!   primitives shared by both fast engines (the **scalar reference
//!   tier** of the kernel dispatch).
//! * [`simd`] — runtime-dispatched SIMD tiers (AVX2 / NEON / portable)
//!   for the popcount hot kernels, resolved once per compiled model via
//!   a [`Kernels`] table and overridable with `TINBINN_SIMD`. Every
//!   tier is pinned bit-exact to the scalar reference by `proptests`.
//!
//! Numeric contract (DESIGN.md): u8 activations, ±1 weights, i32
//! accumulation, per-channel i32 bias, per-layer round-half-up right
//! shift, clamp to 0..255; the SVM head emits raw i32 scores. The paper's
//! exact hardware pipeline (i16 partial sums per 16 input maps, widened by
//! the quad add) is available via [`grouped`] for the overflow audit.

pub mod bitplane;
pub mod floatref;
pub mod grouped;
pub mod layers;
pub mod opt;
pub mod pack;
pub mod simd;

pub use bitplane::BitplaneModel;
pub use layers::{conv3x3_binary, dense_binary, forward, maxpool2, quant_act, Tensor3};
pub use opt::{OptModel, Scratch};
pub use pack::PackedLayer;
pub use simd::{Kernels, KernelTier};

#[cfg(test)]
mod proptests;
