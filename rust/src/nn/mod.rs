//! S5: golden fixed-point NN library — the bit-exact reference for the
//! overlay simulator, the JAX fixed model, and the PJRT artifact.
//!
//! Numeric contract (DESIGN.md): u8 activations, ±1 weights, i32
//! accumulation, per-channel i32 bias, per-layer round-half-up right
//! shift, clamp to 0..255; the SVM head emits raw i32 scores. The paper's
//! exact hardware pipeline (i16 partial sums per 16 input maps, widened by
//! the quad add) is available via [`grouped`] for the overflow audit.

pub mod floatref;
pub mod grouped;
pub mod layers;

pub use layers::{conv3x3_binary, dense_binary, forward, maxpool2, quant_act, Tensor3};

#[cfg(test)]
mod proptests;
