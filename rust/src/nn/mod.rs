//! S5: fixed-point NN library — two engines over one numeric contract.
//!
//! * [`layers`] — the **golden model**: the bit-exact, obviousness-first
//!   reference for the overlay simulator, the JAX fixed model, and the
//!   PJRT artifact. Never optimized; it is the oracle.
//! * [`opt`] — the **fast path**: blocked, bit-packed, fused inference
//!   (packed-word sign trick, scratch arena, zero per-layer
//!   allocations). Bit-exact with the golden model; `proptests` pins the
//!   two together over randomized nets.
//! * [`pack`] — packed-weight preparation shared by the fast path.
//!
//! Numeric contract (DESIGN.md): u8 activations, ±1 weights, i32
//! accumulation, per-channel i32 bias, per-layer round-half-up right
//! shift, clamp to 0..255; the SVM head emits raw i32 scores. The paper's
//! exact hardware pipeline (i16 partial sums per 16 input maps, widened by
//! the quad add) is available via [`grouped`] for the overflow audit.

pub mod floatref;
pub mod grouped;
pub mod layers;
pub mod opt;
pub mod pack;

pub use layers::{conv3x3_binary, dense_binary, forward, maxpool2, quant_act, Tensor3};
pub use opt::{OptModel, Scratch};
pub use pack::PackedLayer;

#[cfg(test)]
mod proptests;
