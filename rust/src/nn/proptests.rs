//! Property tests on the golden NN (in-tree generator — see testkit),
//! including the differential suites pinning the nn::opt fast path AND
//! the nn::bitplane popcount engine to the golden oracle over
//! randomized shapes, weights and images. The engine differentials run
//! under **every kernel tier the host supports** (scalar / portable /
//! avx2 / neon), so each SIMD path is pinned bit-exact to the oracle,
//! not just whichever tier auto-detection picked.

use crate::model::weights::{random_params, LayerParams};
use crate::model::zoo::{Layer, Net};
use crate::nn::bitplane;
use crate::nn::layers::*;
use crate::nn::opt;
use crate::nn::pack::{pack_planes, PackedLayer};
use crate::nn::simd::{Kernels, KernelTier};
use crate::testkit::Arbitrary;
use crate::util::Rng64;

fn rand_layer(rng: &mut Rng64, k_in: usize, n_out: usize) -> LayerParams {
    let kw = (k_in + 31) / 32;
    LayerParams {
        k_in,
        n_out,
        words: (0..n_out * kw).map(|_| rng.next_u32()).collect(),
        bias: (0..n_out).map(|_| rng.below(200) as i32 - 100).collect(),
        shift: (rng.below(8)) as u8,
    }
}

#[test]
fn prop_conv_linearity_in_input_scale() {
    // conv(2x) == 2*conv(x) for accumulators (pure ±1 linear op)
    crate::testkit::check(200, |rng| {
        let h = 2 + rng.below(5) as usize;
        let w = 2 + rng.below(5) as usize;
        let c = 1 + rng.below(3) as usize;
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8() / 2).collect();
        let x1 = Tensor3::from_u8(h, w, c, &img);
        let img2: Vec<u8> = img.iter().map(|&v| v * 2).collect();
        let x2 = Tensor3::from_u8(h, w, c, &img2);
        let n_out = 1 + rng.below(4) as usize;
        let p = rand_layer(rng, 9 * c, n_out);
        let a = conv3x3_binary(&x1, &p);
        let b = conv3x3_binary(&x2, &p);
        for i in 0..a.data.len() {
            assert_eq!(2 * a.data[i], b.data[i]);
        }
    });
}

#[test]
fn prop_conv_bounded_by_window_mass() {
    // |acc| <= sum of window activations (weights are ±1)
    crate::testkit::check(100, |rng| {
        let h = 2 + rng.below(6) as usize;
        let w = 2 + rng.below(6) as usize;
        let c = 1 + rng.below(3) as usize;
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let x = Tensor3::from_u8(h, w, c, &img);
        let p = rand_layer(rng, 9 * c, 2);
        let out = conv3x3_binary(&x, &p);
        let total: i64 = img.iter().map(|&v| v as i64).sum();
        for v in &out.data {
            assert!((*v as i64).abs() <= total);
        }
    });
}

#[test]
fn prop_quant_output_in_u8_range() {
    crate::testkit::check(300, |rng| {
        let acc = (rng.next_u32() as i32).wrapping_mul(3);
        let bias = rng.below(10_000) as i32 - 5_000;
        let shift = rng.below(16) as u8;
        let q = quant_scalar(acc, bias, shift);
        assert!((0..=255).contains(&q));
    });
}

#[test]
fn prop_quant_monotonic_in_acc() {
    crate::testkit::check(200, |rng| {
        let bias = rng.below(1000) as i32 - 500;
        let shift = rng.below(12) as u8;
        let a = rng.below(1 << 20) as i32 - (1 << 19);
        let b = a + rng.below(1 << 10) as i32;
        assert!(quant_scalar(a, bias, shift) <= quant_scalar(b, bias, shift));
    });
}

#[test]
fn prop_maxpool_idempotent_on_constant() {
    crate::testkit::check(50, |rng| {
        let h = 2 * (1 + rng.below(4) as usize);
        let w = 2 * (1 + rng.below(4) as usize);
        let c = 1 + rng.below(4) as usize;
        let v = rng.next_u8() as i32;
        let x = Tensor3 { h, w, c, data: vec![v; h * w * c] };
        let out = maxpool2(&x);
        assert!(out.data.iter().all(|&o| o == v));
    });
}

#[test]
fn prop_maxpool_dominates_every_element() {
    crate::testkit::check(100, |rng| {
        let h = 2 * (1 + rng.below(3) as usize);
        let w = 2 * (1 + rng.below(3) as usize);
        let x = Tensor3 {
            h,
            w,
            c: 1,
            data: (0..h * w).map(|_| rng.next_u8() as i32).collect(),
        };
        let out = maxpool2(&x);
        for y in 0..h {
            for xp in 0..w {
                assert!(out.at(y / 2, xp / 2, 0) >= x.at(y, xp, 0));
            }
        }
    });
}

#[test]
fn prop_dense_flip_one_bit_changes_by_2x() {
    // flipping weight bit k changes the output by exactly ±2*x[k]
    crate::testkit::check(100, |rng| {
        let k_in = 1 + rng.below(60) as usize;
        let mut p = rand_layer(rng, k_in, 1);
        let flat: Vec<i32> = (0..k_in).map(|_| rng.next_u8() as i32).collect();
        let before = dense_binary(&flat, &p)[0];
        let k = rng.below(k_in as u32) as usize;
        let sign_before = p.weight(0, k);
        p.words[k / 32] ^= 1 << (k % 32);
        let after = dense_binary(&flat, &p)[0];
        assert_eq!(after - before, -2 * sign_before * flat[k]);
    });
}

#[test]
fn prop_forward_deterministic() {
    use crate::model::zoo::tiny_1cat;
    let np = random_params(&tiny_1cat(), 11);
    let mut rng = Rng64::new(2);
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u8()).collect();
    let a = forward(&np, &img).unwrap();
    let b = forward(&np, &img).unwrap();
    assert_eq!(a, b);
}

// ---- golden vs nn::opt differential suite ------------------------------
//
// The golden model is the oracle; the fast path must be bit-exact on
// every shape it supports. These properties randomize geometry (incl.
// 1-channel, non-square maps, 1-category heads), weights (incl. stray
// tail bits in the last packed word), and images.

/// Random small net: conv stacks, optional pool, optional dense,
/// 1..4-category SVM head, on a random (possibly non-square) input.
fn rand_net(rng: &mut Rng64) -> Net {
    let h = 2 * (2 + rng.below(3) as usize); // 4, 6, 8
    let w = 2 * (2 + rng.below(4) as usize); // 4..10, often != h
    let c = 1 + rng.below(3) as usize; // incl. single-channel
    let mut layers = vec![Layer::Conv3x3 { cout: 1 + rng.below(6) as usize }];
    if rng.below(2) == 1 {
        layers.push(Layer::Conv3x3 { cout: 1 + rng.below(4) as usize });
    }
    layers.push(Layer::MaxPool2);
    if rng.below(2) == 1 {
        layers.push(Layer::Dense { nout: 1 + rng.below(8) as usize });
    }
    layers.push(Layer::Svm { nout: 1 + rng.below(4) as usize }); // incl. 1-cat
    Net { name: "prop".into(), input_hwc: (h, w, c), layers }
}

#[test]
fn prop_opt_forward_matches_golden() {
    crate::testkit::check(40, |rng| {
        let net = rand_net(rng);
        let np = random_params(&net, rng.next_u64());
        let (h, w, c) = net.input_hwc;
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let golden = forward(&np, &img).unwrap();
        let mut scratch = opt::Scratch::new();
        for tier in KernelTier::available() {
            let model = opt::OptModel::with_tier(&np, tier).unwrap();
            let fast = model.forward(&img, &mut scratch).unwrap();
            assert_eq!(golden, fast, "tier {tier} net {:?} input {h}x{w}x{c}", net.layers);
        }
    });
}

#[test]
fn prop_opt_conv_kernel_matches_golden() {
    crate::testkit::check(100, |rng| {
        let h = 1 + rng.below(7) as usize;
        let w = 1 + rng.below(7) as usize;
        let c = 1 + rng.below(4) as usize;
        let n_out = 1 + rng.below(5) as usize;
        let p = rand_layer(rng, 9 * c, n_out);
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let x = Tensor3::from_u8(h, w, c, &img);
        let golden = quant_act(&conv3x3_binary(&x, &p), &p.bias, p.shift);
        let pl = PackedLayer::prepare(&p).unwrap();
        let src: Vec<i32> = img.iter().map(|&b| b as i32).collect();
        let mut win = vec![0i32; 9 * c];
        let mut cols = vec![0i32; w];
        let mut dst = vec![0i32; h * w * n_out];
        for tier in KernelTier::available() {
            let k = Kernels::for_tier(tier).unwrap();
            opt::conv3x3_requant(&src, h, w, c, &pl, &mut win, &mut cols, &mut dst, &k);
            assert_eq!(dst, golden.data, "tier {tier} {h}x{w}x{c} -> {n_out}");
        }
    });
}

#[test]
fn prop_opt_dense_matches_golden() {
    crate::testkit::check(150, |rng| {
        // k_in deliberately hits word-aligned and ragged sizes
        let k_in = 1 + rng.below(130) as usize;
        let n_out = 1 + rng.below(6) as usize;
        let p = rand_layer(rng, k_in, n_out);
        let flat: Vec<i32> = (0..k_in).map(|_| rng.next_u8() as i32).collect();
        let golden = dense_binary(&flat, &p);
        let pl = PackedLayer::prepare(&p).unwrap();
        let mut out = vec![0i32; n_out];
        for tier in KernelTier::available() {
            let k = Kernels::for_tier(tier).unwrap();
            opt::dense_binary_fast(&flat, &pl, &mut out, &k);
            assert_eq!(out, golden, "tier {tier}");
        }
    });
}

// ---- golden vs nn::bitplane differential suite -------------------------
//
// The popcount engine gets the same contract as nn::opt: bit-exact with
// the golden oracle on every supported shape, including non-word-aligned
// K (stray tail bits in the last packed word), all-border feature maps,
// and the full zoo nets.

#[test]
fn prop_bitplane_forward_matches_golden() {
    crate::testkit::check(40, |rng| {
        let net = rand_net(rng);
        let np = random_params(&net, rng.next_u64());
        let (h, w, c) = net.input_hwc;
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let golden = forward(&np, &img).unwrap();
        let mut scratch = bitplane::Scratch::new();
        for tier in KernelTier::available() {
            let model = bitplane::BitplaneModel::with_tier(&np, tier).unwrap();
            let fast = model.forward(&img, &mut scratch).unwrap();
            assert_eq!(golden, fast, "tier {tier} net {:?} input {h}x{w}x{c}", net.layers);
        }
    });
}

#[test]
fn prop_bitplane_conv_kernel_matches_golden() {
    crate::testkit::check(100, |rng| {
        let h = 1 + rng.below(7) as usize;
        let w = 1 + rng.below(7) as usize;
        let c = 1 + rng.below(4) as usize;
        let n_out = 1 + rng.below(5) as usize;
        let p = rand_layer(rng, 9 * c, n_out);
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let x = Tensor3::from_u8(h, w, c, &img);
        let golden = quant_act(&conv3x3_binary(&x, &p), &p.bias, p.shift);
        let pl = PackedLayer::prepare(&p).unwrap();
        let src: Vec<i32> = img.iter().map(|&b| b as i32).collect();
        let mut win = vec![0i32; 9 * c];
        let mut planes = vec![0u32; 8 * pl.kw];
        let mut dst = vec![0i32; h * w * n_out];
        for tier in KernelTier::available() {
            let k = Kernels::for_tier(tier).unwrap();
            bitplane::conv3x3_bitplane(&src, h, w, c, &pl, &mut win, &mut planes, &mut dst, &k);
            assert_eq!(dst, golden.data, "tier {tier} {h}x{w}x{c} -> {n_out}");
        }
    });
}

#[test]
fn prop_bitplane_conv_all_border_maps() {
    // h, w <= 3: every output pixel touches the zero-padding
    crate::testkit::check(80, |rng| {
        let h = 1 + rng.below(3) as usize;
        let w = 1 + rng.below(3) as usize;
        let c = 1 + rng.below(4) as usize;
        let n_out = 1 + rng.below(4) as usize;
        let p = rand_layer(rng, 9 * c, n_out);
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let x = Tensor3::from_u8(h, w, c, &img);
        let golden = quant_act(&conv3x3_binary(&x, &p), &p.bias, p.shift);
        let pl = PackedLayer::prepare(&p).unwrap();
        let src: Vec<i32> = img.iter().map(|&b| b as i32).collect();
        let mut win = vec![0i32; 9 * c];
        let mut planes = vec![0u32; 8 * pl.kw];
        let mut dst = vec![0i32; h * w * n_out];
        for tier in KernelTier::available() {
            let k = Kernels::for_tier(tier).unwrap();
            bitplane::conv3x3_bitplane(&src, h, w, c, &pl, &mut win, &mut planes, &mut dst, &k);
            assert_eq!(dst, golden.data, "tier {tier} all-border {h}x{w}x{c} -> {n_out}");
        }
    });
}

#[test]
fn prop_bitplane_dense_matches_golden() {
    crate::testkit::check(150, |rng| {
        // k_in deliberately hits word-aligned and ragged sizes
        let k_in = 1 + rng.below(130) as usize;
        let n_out = 1 + rng.below(6) as usize;
        let p = rand_layer(rng, k_in, n_out);
        let flat: Vec<i32> = (0..k_in).map(|_| rng.next_u8() as i32).collect();
        let golden = dense_binary(&flat, &p);
        let pl = PackedLayer::prepare(&p).unwrap();
        let mut planes = vec![0u32; 8 * pl.kw];
        let mut out = vec![0i32; n_out];
        for tier in KernelTier::available() {
            let k = Kernels::for_tier(tier).unwrap();
            bitplane::dense_bitplane(&flat, &pl, &mut planes, &mut out, &k);
            assert_eq!(out, golden, "tier {tier}");
        }
    });
}

#[test]
fn bitplane_matches_golden_on_full_zoo_nets() {
    use crate::model::zoo::{reduced_10cat, tiny_1cat};
    let mut rng = Rng64::new(77);
    for (seed, net) in [(31u64, tiny_1cat()), (32, reduced_10cat())] {
        let np = random_params(&net, seed);
        let model = bitplane::BitplaneModel::new(&np).unwrap();
        let mut scratch = bitplane::Scratch::new();
        let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u8()).collect();
        let golden = forward(&np, &img).unwrap();
        assert_eq!(golden, model.forward(&img, &mut scratch).unwrap(), "{}", net.name);
    }
}

// ---- golden vs overlay-simulator differential suite --------------------
//
// The compile -> Board::infer path gets the same contract as the CPU
// engines: bit-exact with the golden oracle on randomized small zoo
// nets (arbitrary non-square inputs, single-channel maps, 1..4-category
// heads) — not just the two paper networks.

#[test]
fn prop_overlay_forward_matches_golden() {
    use crate::compiler::lower::{compile, InputMode};
    use crate::soc::Board;
    crate::testkit::check(20, |rng| {
        let net = rand_net(rng);
        let np = random_params(&net, rng.next_u64());
        let (h, w, c) = net.input_hwc;
        let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
        let golden = forward(&np, &img).unwrap();
        let compiled = compile(&np, InputMode::Direct).unwrap();
        let mut board = Board::new(&compiled);
        let (sim, report) = board.infer(&compiled, &img).unwrap();
        assert_eq!(
            golden, sim,
            "overlay != golden: net {:?} input {h}x{w}x{c}",
            net.layers
        );
        assert!(report.total_cycles > 0);
    });
}

#[test]
fn overlay_rejects_wrong_input_length_for_small_nets() {
    use crate::compiler::lower::{compile, InputMode};
    use crate::soc::Board;
    let net = Net {
        name: "prop".into(),
        input_hwc: (4, 6, 2),
        layers: vec![Layer::Conv3x3 { cout: 3 }, Layer::MaxPool2, Layer::Svm { nout: 2 }],
    };
    let np = random_params(&net, 5);
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    // the compiled net carries its own input geometry now
    assert!(board.infer(&compiled, &[0u8; 3072]).is_err());
    assert!(board.infer(&compiled, &vec![0u8; 4 * 6 * 2]).is_ok());
}

#[test]
fn prop_bitplane_scratch_reuse_is_stateless() {
    // one arena across many different nets/images must never leak state
    crate::testkit::check(20, |rng| {
        let mut scratch = bitplane::Scratch::new();
        for _ in 0..3 {
            let net = rand_net(rng);
            let np = random_params(&net, rng.next_u64());
            let (h, w, c) = net.input_hwc;
            let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
            let model = bitplane::BitplaneModel::new(&np).unwrap();
            let fast = model.forward(&img, &mut scratch).unwrap();
            assert_eq!(fast, forward(&np, &img).unwrap());
        }
    });
}

#[test]
fn prop_opt_scratch_reuse_is_stateless() {
    // one arena across many different nets/images must never leak state
    crate::testkit::check(20, |rng| {
        let mut scratch = opt::Scratch::new();
        for _ in 0..3 {
            let net = rand_net(rng);
            let np = random_params(&net, rng.next_u64());
            let (h, w, c) = net.input_hwc;
            let img: Vec<u8> = (0..h * w * c).map(|_| rng.next_u8()).collect();
            let model = opt::OptModel::new(&np).unwrap();
            let fast = model.forward(&img, &mut scratch).unwrap();
            assert_eq!(fast, forward(&np, &img).unwrap());
        }
    });
}

// ---- kernel-tier agreement + batched-forward suite ---------------------
//
// The SIMD dispatch contract: every tier is a drop-in for the scalar
// reference (same outputs on every input, including ragged K where the
// vector path hands the tail to a scalar walk), and the image-major
// batched forward is a pure reordering of the single-image path.

#[test]
fn prop_kernel_tiers_agree_on_tail_masked_planes() {
    // forced-portable vs auto-detected tier on randomized ragged K:
    // identical plane_popcounts / bitplane_dot / plus_sum, always.
    let portable = Kernels::for_tier(KernelTier::Portable).unwrap();
    let detected = Kernels::for_tier(KernelTier::detect()).unwrap();
    crate::testkit::check(150, |rng| {
        // deliberately non-word-aligned K most of the time
        let k_in = 1 + rng.below(300) as usize;
        let n_out = 1 + rng.below(5) as usize;
        let p = rand_layer(rng, k_in, n_out);
        let pl = PackedLayer::prepare(&p).unwrap();
        let vals: Vec<i32> = (0..k_in).map(|_| rng.next_u8() as i32).collect();
        let mut planes = vec![0u32; 8 * pl.kw];
        pack_planes(&vals, &mut planes);
        let pops_p = (portable.plane_popcounts)(&planes);
        let pops_d = (detected.plane_popcounts)(&planes);
        assert_eq!(pops_p, pops_d, "plane_popcounts K={k_in}");
        for n in 0..n_out {
            assert_eq!(
                (portable.plus_sum)(pl.row(n), &vals),
                (detected.plus_sum)(pl.row(n), &vals),
                "plus_sum K={k_in} row={n}"
            );
            assert_eq!(
                (portable.bitplane_dot)(pl.row(n), &planes, &pops_p),
                (detected.bitplane_dot)(pl.row(n), &planes, &pops_d),
                "bitplane_dot K={k_in} row={n}"
            );
        }
    });
}

#[test]
fn prop_batched_forward_matches_single_image() {
    // image-major blocked batches (sizes crossing BATCH_BLOCK) must be
    // bit-exact with serial single-image forwards and with the oracle,
    // on both fast engines.
    crate::testkit::check(15, |rng| {
        let net = rand_net(rng);
        let np = random_params(&net, rng.next_u64());
        let (h, w, c) = net.input_hwc;
        // 1..=2*BATCH_BLOCK+2: partial, exact, and multi-block batches
        let nimg = 1 + rng.below(2 * opt::BATCH_BLOCK as u32 + 2) as usize;
        let imgs: Vec<Vec<u8>> = (0..nimg)
            .map(|_| (0..h * w * c).map(|_| rng.next_u8()).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let golden: Vec<Vec<i32>> =
            imgs.iter().map(|img| forward(&np, img).unwrap()).collect();

        let opt_model = opt::OptModel::new(&np).unwrap();
        let mut opt_scratch = opt::Scratch::new();
        let mut batched = Vec::new();
        opt_model.forward_batch_into(&refs, &mut opt_scratch, &mut batched).unwrap();
        assert_eq!(batched, golden, "opt batch of {nimg}");
        for (img, want) in imgs.iter().zip(&golden) {
            assert_eq!(&opt_model.forward(img, &mut opt_scratch).unwrap(), want);
        }

        let bp_model = bitplane::BitplaneModel::new(&np).unwrap();
        let mut bp_scratch = bitplane::Scratch::new();
        let mut bp_batched = Vec::new();
        bp_model.forward_batch_into(&refs, &mut bp_scratch, &mut bp_batched).unwrap();
        assert_eq!(bp_batched, golden, "bitplane batch of {nimg}");
    });
}

// keep Arbitrary referenced until more generators land
#[allow(dead_code)]
fn _touch(_: &dyn Arbitrary) {}
