//! S11: the frame-pipeline coordinator — the L3 "product" around the
//! overlay: frame sources, dynamic batching, inference backends,
//! backpressure, and latency/throughput metrics.
//!
//! Three deployment shapes, matching the paper's two §II comparisons
//! plus the serving north star:
//!
//! * **Embedded**: camera frames → preprocessing → the overlay
//!   simulator, one frame at a time (the MDP person detector).
//! * **Desktop**: request stream → dynamic batcher → AOT-compiled XLA
//!   executables via PJRT (the i7 baseline re-cast as a serving path
//!   with b1/b4/b8 variants).
//! * **Gateway**: a multi-model front door (`registry` + `gateway`)
//!   routing tagged requests — the paper's two detectors served from
//!   one process — across per-model sharded worker pools on any mix of
//!   engines, with deadlines, priorities, load shedding and exact
//!   accounting.

pub mod backend;
pub mod batcher;
pub mod gateway;
pub mod metrics;
pub mod pipeline;
pub mod registry;

pub use backend::{Backend, BitplaneBackend, GoldenBackend, OptBackend, OverlayBackend};
pub use batcher::{Batcher, BatchPolicy, Priority};
pub use gateway::{
    serve_gateway, DrainHandle, GatewayConfig, GatewayLane, GatewayReport, GatewayRequest,
    ModelReport, Router,
};
pub use metrics::{Histogram, Meter};
pub use pipeline::{run_stream, serve_parallel, Frame, PipelineReport, StreamConfig};
pub use registry::{parse_model_specs, AnyBackend, BackendKind, ModelRegistry, ModelSpec};
