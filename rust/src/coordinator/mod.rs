//! S11: the frame-pipeline coordinator — the L3 "product" around the
//! overlay: frame sources, dynamic batching, inference backends,
//! backpressure, and latency/throughput metrics.
//!
//! Two deployment shapes, matching the paper's two §II comparisons:
//!
//! * **Embedded**: camera frames → preprocessing → the overlay
//!   simulator, one frame at a time (the MDP person detector).
//! * **Desktop**: request stream → dynamic batcher → AOT-compiled XLA
//!   executables via PJRT (the i7 baseline re-cast as a serving path
//!   with b1/b4/b8 variants).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod pipeline;

pub use backend::{Backend, OptBackend, OverlayBackend};
pub use batcher::{Batcher, BatchPolicy};
pub use metrics::{Histogram, Meter};
pub use pipeline::{run_stream, serve_parallel, Frame, PipelineReport, StreamConfig};
