//! Latency histograms + throughput meters for the pipeline.

/// Log-bucketed latency histogram (microseconds, 1us .. ~17min).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us
    buckets: [u64; 30],
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: [0; 30], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(29);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Fold another histogram into this one (multi-worker merge).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Median latency estimate (shorthand used by report rows).
    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.5)
    }

    /// Tail latency estimate (shorthand used by report rows).
    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }

    /// Quantile estimate for `q` (0..=1): linear interpolation inside
    /// the winning log bucket, clamped to the observed maximum. The old
    /// bucket-upper-bound answer overstated p99 by up to 2x; the clamp
    /// only ever bites in the top non-empty bucket (every lower bucket's
    /// upper bound is <= max_us), which keeps the result monotone in `q`
    /// and makes `quantile_us(1.0) == max_us` exact.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil().max(1.0) as u64).min(self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = 1u64 << i;
                let hi = if i == 29 { self.max_us.max(lo + 1) } else { 1u64 << (i + 1) };
                let frac = (target - seen) as f64 / c as f64;
                let v = (lo as f64 + frac * hi.saturating_sub(lo) as f64).round() as u64;
                return v.min(self.max_us);
            }
            seen += c;
        }
        self.max_us
    }

    /// Raw log-bucket counts: bucket `i` holds samples in [2^i, 2^(i+1)) us.
    pub fn buckets(&self) -> &[u64; 30] {
        &self.buckets
    }

    /// Rebuild a histogram from raw parts (snapshot materialization).
    pub fn from_parts(buckets: [u64; 30], count: u64, sum_us: u64, max_us: u64) -> Self {
        Histogram { buckets, count, sum_us, max_us }
    }
}

/// Throughput meter over an injected clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct Meter {
    pub events: u64,
    pub start_us: u64,
    pub end_us: u64,
}

impl Meter {
    pub fn record(&mut self, now_us: u64, n: u64) {
        if self.events == 0 {
            self.start_us = now_us;
        }
        self.events += n;
        self.end_us = self.end_us.max(now_us);
    }

    pub fn per_second(&self) -> f64 {
        let span = self.end_us.saturating_sub(self.start_us);
        if span == 0 {
            return 0.0;
        }
        self.events as f64 * 1e6 / span as f64
    }

    /// Fold another meter into this one (fleet-report merge): events add,
    /// the observation window becomes the union of the two windows.
    pub fn merge(&mut self, other: &Meter) {
        if other.events == 0 {
            return;
        }
        if self.events == 0 {
            *self = *other;
            return;
        }
        self.start_us = self.start_us.min(other.start_us);
        self.end_us = self.end_us.max(other.end_us);
        self.events += other.events;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 30, 1000, 2000, 100_000] {
            h.record(us);
        }
        assert_eq!(h.count, 6);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert_eq!(h.p50_us(), h.quantile_us(0.5));
        assert_eq!(h.p99_us(), h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us, 100_000);
    }

    #[test]
    fn merge_is_equivalent_to_recording_everything() {
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, us) in [5u64, 50, 500, 5000, 50_000, 500_000].iter().enumerate() {
            all.record(*us);
            if i % 2 == 0 {
                a.record(*us);
            } else {
                b.record(*us);
            }
        }
        a.merge(&b);
        assert_eq!(a.count, all.count);
        assert_eq!(a.sum_us, all.sum_us);
        assert_eq!(a.max_us, all.max_us);
        assert_eq!(a.quantile_us(0.5), all.quantile_us(0.5));
        assert_eq!(a.quantile_us(0.99), all.quantile_us(0.99));
    }

    #[test]
    fn quantile_interpolates_within_the_winning_bucket() {
        // 100 identical samples at 1000us live in bucket [512, 1024);
        // the old code answered 1024 (bucket upper bound) for every q.
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(1000);
        }
        assert_eq!(h.quantile_us(1.0), 1000, "p100 is the exact max");
        assert!(h.quantile_us(0.99) <= 1000, "p99 never exceeds the max sample");
        let p50 = h.quantile_us(0.5);
        assert!((512..=1000).contains(&p50), "p50 interpolates inside the bucket: {p50}");
        assert!(h.p99_us() < 1024, "no more bucket-upper-bound overstatement");
        // raw bucket exposition for snapshot rendering
        assert_eq!(h.buckets()[9], 100);
        assert_eq!(h.buckets().iter().sum::<u64>(), h.count);
        // parts round-trip
        let back = Histogram::from_parts(*h.buckets(), h.count, h.sum_us, h.max_us);
        assert_eq!(back.quantile_us(0.99), h.quantile_us(0.99));
    }

    #[test]
    fn histogram_zero_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn meter_rate() {
        let mut m = Meter::default();
        m.record(0, 1);
        m.record(1_000_000, 99);
        assert!((m.per_second() - 100.0).abs() < 1.0);
    }

    #[test]
    fn meter_merge_adds_events_and_unions_windows() {
        let mut a = Meter::default();
        let mut b = Meter::default();
        let mut all = Meter::default();
        a.record(100, 3);
        all.record(100, 3);
        b.record(50, 2);
        all.record(50, 2);
        b.record(1_000_000, 5);
        all.record(1_000_000, 5);
        a.merge(&b);
        assert_eq!(a.events, all.events);
        assert_eq!(a.start_us, 50);
        assert_eq!(a.end_us, 1_000_000);
        // merging an empty meter is a no-op in both directions
        let empty = Meter::default();
        let before = a;
        a.merge(&empty);
        assert_eq!(a.events, before.events);
        let mut e = Meter::default();
        e.merge(&a);
        assert_eq!(e.per_second(), a.per_second());
    }

    #[test]
    fn prop_quantile_monotone_in_q() {
        // quantile_us must be non-decreasing in q over arbitrary samples
        crate::testkit::check(50, |rng| {
            let mut h = Histogram::new();
            let n = 1 + rng.below(200);
            for _ in 0..n {
                h.record(1 + rng.below(2_000_000) as u64);
            }
            let qs = [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
            for w in qs.windows(2) {
                assert!(
                    h.quantile_us(w[0]) <= h.quantile_us(w[1]),
                    "quantile not monotone: q{} -> {} > q{} -> {}",
                    w[0],
                    h.quantile_us(w[0]),
                    w[1],
                    h.quantile_us(w[1])
                );
            }
        });
    }

    #[test]
    fn prop_merge_equals_concatenated_recording() {
        // merge(a, b) must be indistinguishable from recording the
        // concatenated sample stream into one histogram
        crate::testkit::check(50, |rng| {
            let mut a = Histogram::new();
            let mut b = Histogram::new();
            let mut all = Histogram::new();
            let n = rng.below(150);
            for _ in 0..n {
                let v = rng.below(5_000_000) as u64;
                all.record(v);
                if rng.below(2) == 0 {
                    a.record(v);
                } else {
                    b.record(v);
                }
            }
            a.merge(&b);
            assert_eq!(a.count, all.count);
            assert_eq!(a.sum_us, all.sum_us);
            assert_eq!(a.max_us, all.max_us);
            for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
                assert_eq!(a.quantile_us(q), all.quantile_us(q), "q={q}");
            }
        });
    }

    #[test]
    fn prop_meter_per_second_stable_under_split_recording() {
        // recording n events at time t in one call or split across many
        // calls (any partition, any order) gives the same rate
        crate::testkit::check(50, |rng| {
            let n_ticks = 2 + rng.below(20);
            let ticks: Vec<(u64, u64)> = (0..n_ticks)
                .map(|_| (rng.below(1_000_000) as u64, 1 + rng.below(40) as u64))
                .collect();
            let mut whole = Meter::default();
            let mut split = Meter::default();
            for &(t, n) in &ticks {
                whole.record(t, n);
                // split the same n events at the same instant
                let cut = rng.below(n as u32 + 1) as u64;
                split.record(t, cut);
                split.record(t, n - cut);
            }
            assert_eq!(whole.events, split.events);
            assert!((whole.per_second() - split.per_second()).abs() < 1e-9);
        });
    }

    #[test]
    fn prop_quantile_bounds_contain_samples() {
        crate::testkit::check(50, |rng| {
            let mut h = Histogram::new();
            let mut max = 0u64;
            for _ in 0..100 {
                let v = 1 + rng.below(1_000_000) as u64;
                h.record(v);
                max = max.max(v);
            }
            // p100 bucket bound >= max sample (bucket upper bound)
            assert!(h.quantile_us(1.0) >= max || h.quantile_us(1.0) == h.max_us);
        });
    }
}
