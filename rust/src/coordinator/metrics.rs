//! Latency histograms + throughput meters for the pipeline.

/// Log-bucketed latency histogram (microseconds, 1us .. ~17min).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us
    buckets: [u64; 30],
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: [0; 30], count: 0, sum_us: 0, max_us: 0 }
    }

    pub fn record(&mut self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(29);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Fold another histogram into this one (multi-worker merge).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Upper bound of the bucket containing quantile `q` (0..1).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }
}

/// Throughput meter over an injected clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct Meter {
    pub events: u64,
    pub start_us: u64,
    pub end_us: u64,
}

impl Meter {
    pub fn record(&mut self, now_us: u64, n: u64) {
        if self.events == 0 {
            self.start_us = now_us;
        }
        self.events += n;
        self.end_us = self.end_us.max(now_us);
    }

    pub fn per_second(&self) -> f64 {
        let span = self.end_us.saturating_sub(self.start_us);
        if span == 0 {
            return 0.0;
        }
        self.events as f64 * 1e6 / span as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::new();
        for us in [10u64, 20, 30, 1000, 2000, 100_000] {
            h.record(us);
        }
        assert_eq!(h.count, 6);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us, 100_000);
    }

    #[test]
    fn merge_is_equivalent_to_recording_everything() {
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, us) in [5u64, 50, 500, 5000, 50_000, 500_000].iter().enumerate() {
            all.record(*us);
            if i % 2 == 0 {
                a.record(*us);
            } else {
                b.record(*us);
            }
        }
        a.merge(&b);
        assert_eq!(a.count, all.count);
        assert_eq!(a.sum_us, all.sum_us);
        assert_eq!(a.max_us, all.max_us);
        assert_eq!(a.quantile_us(0.5), all.quantile_us(0.5));
        assert_eq!(a.quantile_us(0.99), all.quantile_us(0.99));
    }

    #[test]
    fn histogram_zero_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn meter_rate() {
        let mut m = Meter::default();
        m.record(0, 1);
        m.record(1_000_000, 99);
        assert!((m.per_second() - 100.0).abs() < 1.0);
    }

    #[test]
    fn prop_quantile_bounds_contain_samples() {
        crate::testkit::check(50, |rng| {
            let mut h = Histogram::new();
            let mut max = 0u64;
            for _ in 0..100 {
                let v = 1 + rng.below(1_000_000) as u64;
                h.record(v);
                max = max.max(v);
            }
            // p100 bucket bound >= max sample (bucket upper bound)
            assert!(h.quantile_us(1.0) >= max || h.quantile_us(1.0) == h.max_us);
        });
    }
}
