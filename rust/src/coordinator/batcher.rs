//! Dynamic request batcher (vLLM-router-style, sized for this system):
//! requests accumulate until the batch fills or the oldest request has
//! waited `max_wait_us`; a bounded queue applies backpressure upstream.
//! Requests carry an optional absolute deadline and a [`Priority`]: the
//! batcher sheds low-priority work early under load, and the gateway
//! (`coordinator::gateway`) expires overdue requests at dispatch time.

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest batch to dispatch (must match a compiled variant).
    pub max_batch: usize,
    /// Dispatch a partial batch once the oldest request waited this long.
    pub max_wait_us: u64,
    /// Queue capacity; pushes beyond it are rejected (backpressure).
    /// [`Priority::Low`] requests are shed earlier, at half occupancy.
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait_us: 2_000, queue_cap: 64 }
    }
}

/// Request priority: under load, [`Priority::Low`] is shed once the
/// queue is half full, while `Normal`/`High` are only rejected at the
/// full `queue_cap` bound.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

/// A queued request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub enqueue_us: u64,
    pub image: Vec<u8>,
    /// Absolute drop-dead time (same clock as `enqueue_us`); `None`
    /// never expires. Expiry is enforced by dispatch-time filters (the
    /// gateway), not by the batcher itself.
    pub deadline_us: Option<u64>,
    pub priority: Priority,
}

impl Request {
    /// A plain request: no deadline, [`Priority::Normal`].
    pub fn new(id: u64, enqueue_us: u64, image: Vec<u8>) -> Self {
        Request { id, enqueue_us, image, deadline_us: None, priority: Priority::Normal }
    }

    /// True once `now_us` has reached the request's deadline. The
    /// boundary is inclusive: a request dispatched exactly at its
    /// deadline has a zero-remaining budget and is expired, not served —
    /// a deadline of "now" is a promise already broken. (Pinned by the
    /// boundary tests here and in `coordinator::gateway`.)
    pub fn expired(&self, now_us: u64) -> bool {
        matches!(self.deadline_us, Some(d) if now_us >= d)
    }
}

/// Pure batching state machine (time injected — deterministic tests).
pub struct Batcher {
    policy: BatchPolicy,
    queue: std::collections::VecDeque<Request>,
    /// Requests rejected due to a full queue.
    pub rejected: u64,
    /// Total accepted.
    pub accepted: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: Default::default(), rejected: 0, accepted: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Try to enqueue; false = backpressure (caller drops or retries).
    /// [`Priority::Low`] requests are shed once the queue is half full —
    /// cheap early load-shedding that keeps headroom for normal traffic.
    pub fn push(&mut self, req: Request) -> bool {
        let cap = if req.priority == Priority::Low {
            (self.policy.queue_cap / 2).max(1)
        } else {
            self.policy.queue_cap
        };
        if self.queue.len() >= cap {
            self.rejected += 1;
            return false;
        }
        self.accepted += 1;
        self.queue.push_back(req);
        true
    }

    /// Dispatch decision at time `now_us`. Returns a batch in FIFO order
    /// when the policy fires.
    pub fn poll(&mut self, now_us: u64) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now_us.saturating_sub(self.queue.front().unwrap().enqueue_us);
        if self.queue.len() >= self.policy.max_batch || oldest_wait >= self.policy.max_wait_us {
            let n = self.queue.len().min(self.policy.max_batch);
            return Some(self.queue.drain(..n).collect());
        }
        None
    }

    /// Drain everything (shutdown).
    pub fn flush(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: u64) -> Request {
        Request::new(id, t, vec![])
    }

    #[test]
    fn dispatches_full_batch_immediately() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait_us: 1000, queue_cap: 16 });
        for i in 0..4 {
            assert!(b.push(req(i, 0)));
        }
        let batch = b.poll(1).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn waits_for_more_until_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait_us: 1000, queue_cap: 16 });
        b.push(req(0, 100));
        assert!(b.poll(500).is_none()); // only 400us waited
        let batch = b.poll(1100).unwrap(); // 1000us reached
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_fifo_order() {
        let mut b = Batcher::new(BatchPolicy::default());
        for i in 0..8 {
            b.push(req(i, i));
        }
        let batch = b.poll(10).unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn low_priority_shed_at_half_occupancy() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_us: 1000, queue_cap: 8 });
        for i in 0..4 {
            assert!(b.push(req(i, 0)));
        }
        // queue at half cap: Low is shed, Normal and High still admitted
        let low = Request { priority: Priority::Low, ..req(90, 0) };
        assert!(!b.push(low));
        assert_eq!(b.rejected, 1);
        assert!(b.push(req(91, 0)));
        let high = Request { priority: Priority::High, ..req(92, 0) };
        assert!(b.push(high));
    }

    #[test]
    fn low_priority_admitted_when_idle() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_us: 1000, queue_cap: 8 });
        let low = Request { priority: Priority::Low, ..req(0, 0) };
        assert!(b.push(low));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn deadline_expiry_accessor() {
        let mut r = req(0, 100);
        assert!(!r.expired(u64::MAX));
        r.deadline_us = Some(500);
        assert!(!r.expired(499));
        assert!(r.expired(500)); // inclusive boundary: at-deadline is expired
        assert!(r.expired(501));
    }

    #[test]
    fn backpressure_rejects_beyond_cap() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_us: 1000, queue_cap: 2 });
        assert!(b.push(req(0, 0)));
        assert!(b.push(req(1, 0)));
        assert!(!b.push(req(2, 0)));
        assert_eq!(b.rejected, 1);
        assert_eq!(b.accepted, 2);
    }

    // ---- edge policies --------------------------------------------------

    #[test]
    fn max_batch_one_dispatches_each_request_alone() {
        // degenerate batching: every request becomes its own batch, in
        // FIFO order, regardless of how long it waited
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_wait_us: 1_000_000, queue_cap: 16 });
        for i in 0..3 {
            assert!(b.push(req(i, 0)));
        }
        for want in 0..3u64 {
            let batch = b.poll(0).unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch[0].id, want);
        }
        assert!(b.poll(0).is_none());
        assert!(b.is_empty());
    }

    #[test]
    fn zero_timeout_dispatches_immediately() {
        // max_wait_us = 0: a request never waits — the first poll at (or
        // after) its enqueue time fires, even for a batch of one
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_us: 0, queue_cap: 16 });
        b.push(req(0, 500));
        let batch = b.poll(500).unwrap();
        assert_eq!(batch.len(), 1);
        // multiple queued requests still coalesce up to max_batch
        for i in 1..=4 {
            b.push(req(i, 600));
        }
        let batch = b.poll(600).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn zero_timeout_with_max_batch_one_is_pure_passthrough() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_wait_us: 0, queue_cap: 4 });
        assert!(b.push(req(0, 10)));
        assert!(b.push(req(1, 10)));
        assert_eq!(b.poll(10).unwrap()[0].id, 0);
        assert_eq!(b.poll(10).unwrap()[0].id, 1);
        assert!(b.poll(10).is_none());
    }

    #[test]
    fn poll_before_enqueue_time_does_not_underflow() {
        // clock skew: poll at a time earlier than the oldest enqueue must
        // neither panic nor dispatch early (saturating wait math)
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 4 });
        b.push(req(0, 1000));
        assert!(b.poll(500).is_none());
        assert!(b.poll(1100).is_some());
    }

    // ---- property tests (in-tree harness) -------------------------------

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        crate::testkit::check(100, |rng| {
            let policy = BatchPolicy {
                max_batch: 1 + rng.below(8) as usize,
                max_wait_us: rng.below(5000) as u64,
                queue_cap: 4 + rng.below(60) as usize,
            };
            let mut b = Batcher::new(policy);
            let mut now = 0u64;
            let mut sent = Vec::new();
            let mut got = Vec::new();
            let n = 1 + rng.below(200);
            for i in 0..n as u64 {
                now += rng.below(300) as u64;
                if b.push(req(i, now)) {
                    sent.push(i);
                }
                if let Some(batch) = b.poll(now) {
                    got.extend(batch.iter().map(|r| r.id));
                }
            }
            got.extend(b.flush().iter().map(|r| r.id));
            assert_eq!(got, sent, "accepted requests must come out exactly once, in order");
        });
    }

    #[test]
    fn prop_batch_never_exceeds_max() {
        crate::testkit::check(100, |rng| {
            let max_batch = 1 + rng.below(8) as usize;
            let mut b = Batcher::new(BatchPolicy {
                max_batch,
                max_wait_us: rng.below(2000) as u64,
                queue_cap: 64,
            });
            let mut now = 0u64;
            for i in 0..150u64 {
                now += rng.below(100) as u64;
                b.push(req(i, now));
                if let Some(batch) = b.poll(now) {
                    assert!(batch.len() <= max_batch);
                    assert!(!batch.is_empty());
                }
            }
        });
    }

    #[test]
    fn prop_queue_bounded() {
        crate::testkit::check(50, |rng| {
            let cap = 1 + rng.below(30) as usize;
            let mut b = Batcher::new(BatchPolicy { max_batch: 64, max_wait_us: u64::MAX, queue_cap: cap });
            for i in 0..200u64 {
                b.push(req(i, 0));
                assert!(b.len() <= cap, "queue exceeded its bound");
            }
        });
    }

    #[test]
    fn prop_wait_bound_respected() {
        // once poll() is called at/after deadline, the oldest request is
        // always dispatched
        crate::testkit::check(50, |rng| {
            let wait = 1 + rng.below(1000) as u64;
            let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait_us: wait, queue_cap: 100 });
            let t0 = rng.below(10_000) as u64;
            b.push(req(1, t0));
            assert!(b.poll(t0 + wait).is_some());
        });
    }
}
