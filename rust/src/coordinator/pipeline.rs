//! The frame pipeline: arrival stream → batcher → backend → metrics.
//!
//! Deterministic discrete-event loop: frame arrivals follow a configured
//! inter-arrival time; the backend's service time advances the clock.
//! This keeps coordinator behaviour (batching, backpressure, tail
//! latency) exactly reproducible — and a threaded front-end
//! ([`serve_threaded`]) exercises the same components under real
//! concurrency.

use super::backend::Backend;
use super::batcher::{BatchPolicy, Batcher, Request};
use super::metrics::{Histogram, Meter};
use crate::Result;

/// One input frame.
#[derive(Clone, Debug)]
pub struct Frame {
    pub id: u64,
    pub image: Vec<u8>,
    pub label: Option<u8>,
}

/// Stream parameters.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    /// Frame inter-arrival time (us).
    pub interarrival_us: u64,
    /// Backend service time per dispatched batch (us) — for simulated
    /// backends; 0 = measure wall-clock instead.
    pub service_us_per_image: u64,
    pub policy: BatchPolicy,
}

/// Aggregated pipeline results.
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub completed: u64,
    pub rejected: u64,
    pub correct: u64,
    pub labelled: u64,
    pub latency: Option<HistogramSummary>,
    pub throughput_per_s: f64,
    pub batches: u64,
    pub mean_batch: f64,
}

/// Extracted histogram numbers (kept small for reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct HistogramSummary {
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl From<&Histogram> for HistogramSummary {
    fn from(h: &Histogram) -> Self {
        HistogramSummary {
            mean_us: h.mean_us(),
            p50_us: h.quantile_us(0.5),
            p99_us: h.quantile_us(0.99),
            max_us: h.max_us,
        }
    }
}

/// Argmax / threshold classification shared by all reporting paths.
pub fn classify(scores: &[i32]) -> usize {
    crate::nn::layers::classify(scores)
}

/// Run a frame stream through the batcher + backend (discrete-event).
pub fn run_stream<B: Backend>(
    frames: impl IntoIterator<Item = Frame>,
    backend: &mut B,
    cfg: &StreamConfig,
) -> Result<PipelineReport> {
    let mut batcher = Batcher::new(cfg.policy);
    let mut now_us = 0u64;
    let mut latency = Histogram::new();
    let mut meter = Meter::default();
    let mut report = PipelineReport::default();
    let mut batch_sizes = 0u64;

    let dispatch = |now_us: &mut u64,
                        backend: &mut B,
                        batch: Vec<Request>,
                        latency: &mut Histogram,
                        meter: &mut Meter,
                        report: &mut PipelineReport,
                        batch_sizes: &mut u64,
                        labels: &std::collections::HashMap<u64, u8>|
     -> Result<()> {
        let imgs: Vec<&[u8]> = batch.iter().map(|r| r.image.as_slice()).collect();
        let t0 = std::time::Instant::now();
        let scores = backend.infer_batch(&imgs)?;
        let service = if cfg.service_us_per_image > 0 {
            cfg.service_us_per_image * batch.len() as u64
        } else {
            t0.elapsed().as_micros() as u64
        };
        *now_us += service;
        for (req, s) in batch.iter().zip(&scores) {
            latency.record(now_us.saturating_sub(req.enqueue_us));
            report.completed += 1;
            if let Some(&want) = labels.get(&req.id) {
                report.labelled += 1;
                if classify(s) == want as usize {
                    report.correct += 1;
                }
            }
        }
        meter.record(*now_us, batch.len() as u64);
        report.batches += 1;
        *batch_sizes += batch.len() as u64;
        Ok(())
    };

    let mut labels = std::collections::HashMap::new();
    for frame in frames {
        now_us += cfg.interarrival_us;
        if let Some(l) = frame.label {
            labels.insert(frame.id, l);
        }
        let accepted = batcher.push(Request::new(frame.id, now_us, frame.image));
        if !accepted {
            report.rejected += 1;
        }
        while let Some(batch) = batcher.poll(now_us) {
            dispatch(&mut now_us, backend, batch, &mut latency, &mut meter, &mut report, &mut batch_sizes, &labels)?;
        }
    }
    // drain
    let rest = batcher.flush();
    for chunk in rest.chunks(backend.max_batch().max(1)) {
        dispatch(&mut now_us, backend, chunk.to_vec(), &mut latency, &mut meter, &mut report, &mut batch_sizes, &labels)?;
    }

    // (rejections were already counted per push; batcher.rejected tracks
    // the same events — adding it here would double-count)
    report.latency = Some(HistogramSummary::from(&latency));
    report.throughput_per_s = meter.per_second();
    report.mean_batch = if report.batches > 0 {
        batch_sizes as f64 / report.batches as f64
    } else {
        0.0
    };
    Ok(report)
}

/// Threaded serving front-end: a producer thread feeds a bounded channel
/// (real backpressure), a consumer drains into the batcher + backend.
/// Returns the same report shape as [`run_stream`].
pub fn serve_threaded<B: Backend>(
    frames: Vec<Frame>,
    mut backend: B,
    policy: BatchPolicy,
) -> Result<(PipelineReport, B)> {
    use std::sync::mpsc::sync_channel;
    let (tx, rx) = sync_channel::<Frame>(policy.queue_cap);
    let producer = std::thread::spawn(move || {
        for f in frames {
            if tx.send(f).is_err() {
                break;
            }
        }
    });

    let mut batcher = Batcher::new(policy);
    let mut latency = Histogram::new();
    let mut report = PipelineReport::default();
    let mut batch_sizes = 0u64;
    let t_start = std::time::Instant::now();
    let now_us = |t: std::time::Instant| t.elapsed().as_micros() as u64;

    let handle_batch = |batch: Vec<Request>, backend: &mut B, latency: &mut Histogram, report: &mut PipelineReport, batch_sizes: &mut u64| -> Result<()> {
        let imgs: Vec<&[u8]> = batch.iter().map(|r| r.image.as_slice()).collect();
        let scores = backend.infer_batch(&imgs)?;
        let t = now_us(t_start);
        for (req, _s) in batch.iter().zip(&scores) {
            latency.record(t.saturating_sub(req.enqueue_us));
            report.completed += 1;
        }
        report.batches += 1;
        *batch_sizes += batch.len() as u64;
        Ok(())
    };

    loop {
        match rx.recv_timeout(std::time::Duration::from_millis(5)) {
            Ok(frame) => {
                let t = now_us(t_start);
                if !batcher.push(Request::new(frame.id, t, frame.image)) {
                    report.rejected += 1;
                }
                while let Some(batch) = batcher.poll(now_us(t_start)) {
                    handle_batch(batch, &mut backend, &mut latency, &mut report, &mut batch_sizes)?;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                while let Some(batch) = batcher.poll(now_us(t_start)) {
                    handle_batch(batch, &mut backend, &mut latency, &mut report, &mut batch_sizes)?;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    for chunk in batcher.flush().chunks(backend.max_batch().max(1)) {
        handle_batch(chunk.to_vec(), &mut backend, &mut latency, &mut report, &mut batch_sizes)?;
    }
    producer.join().ok();

    let wall = t_start.elapsed().as_secs_f64();
    report.throughput_per_s = report.completed as f64 / wall.max(1e-9);
    report.latency = Some(HistogramSummary::from(&latency));
    report.mean_batch = if report.batches > 0 {
        batch_sizes as f64 / report.batches as f64
    } else {
        0.0
    };
    Ok((report, backend))
}

/// Multi-worker serving front-end: the batcher dispatches onto a shared
/// bounded queue drained by one OS thread per backend
/// (`std::thread::scope`), so a CPU-bound backend (nn::opt, overlay
/// sim) actually scales across cores instead of serializing behind one
/// consumer the way [`serve_threaded`] does.
///
/// Each worker owns its backend, a private latency histogram, and a
/// reusable score buffer: batches are dispatched whole through
/// [`Backend::infer_batch_into`], so a CPU engine worker (nn::opt,
/// nn::bitplane) runs with zero steady-state allocations in the
/// inference path. The histograms are merged after join. Returns the
/// same report shape as [`run_stream`] plus the workers (so callers can
/// inspect per-worker state).
pub fn serve_parallel<B: Backend + Send>(
    frames: Vec<Frame>,
    mut workers: Vec<B>,
    policy: BatchPolicy,
) -> Result<(PipelineReport, Vec<B>)> {
    use std::sync::mpsc::sync_channel;
    use std::sync::Mutex;

    if workers.is_empty() {
        return Err(crate::util::TinError::Config("serve_parallel needs >= 1 worker".into()));
    }
    let max_batch = workers[0].max_batch().max(1);
    let n_workers = workers.len();
    let (btx, brx) = sync_channel::<Vec<Request>>(2 * n_workers);
    let brx = Mutex::new(brx);
    let t_start = std::time::Instant::now();

    struct WorkerTally {
        completed: u64,
        batches: u64,
        batch_sizes: u64,
        latency: Histogram,
    }

    let mut report = PipelineReport::default();
    let tallies: Vec<Result<WorkerTally>> = std::thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter_mut()
            .map(|be| {
                let brx = &brx;
                s.spawn(move || -> Result<WorkerTally> {
                    let mut tally = WorkerTally {
                        completed: 0,
                        batches: 0,
                        batch_sizes: 0,
                        latency: Histogram::new(),
                    };
                    let mut failed: Option<crate::util::TinError> = None;
                    // per-worker reusable score buffer (inner vectors are
                    // recycled across batches by infer_batch_into)
                    let mut scores_buf: Vec<Vec<i32>> = Vec::new();
                    loop {
                        // hold the lock only for the dequeue
                        let batch = match brx.lock().unwrap().recv() {
                            Ok(b) => b,
                            Err(_) => break, // producer done
                        };
                        if failed.is_some() {
                            continue; // keep draining so the producer never blocks
                        }
                        let imgs: Vec<&[u8]> = batch.iter().map(|r| r.image.as_slice()).collect();
                        match be.infer_batch_into(&imgs, &mut scores_buf) {
                            Ok(()) => {
                                let t = t_start.elapsed().as_micros() as u64;
                                for req in &batch {
                                    tally.latency.record(t.saturating_sub(req.enqueue_us));
                                    tally.completed += 1;
                                }
                                tally.batches += 1;
                                tally.batch_sizes += batch.len() as u64;
                            }
                            Err(e) => failed = Some(e),
                        }
                    }
                    match failed {
                        Some(e) => Err(e),
                        None => Ok(tally),
                    }
                })
            })
            .collect();

        // producer side: feed the batcher, dispatch to the queue
        let mut batcher = Batcher::new(policy);
        for frame in frames {
            let now = t_start.elapsed().as_micros() as u64;
            if !batcher.push(Request::new(frame.id, now, frame.image)) {
                report.rejected += 1;
            }
            while let Some(batch) = batcher.poll(t_start.elapsed().as_micros() as u64) {
                if btx.send(batch).is_err() {
                    break;
                }
            }
        }
        for chunk in batcher.flush().chunks(max_batch) {
            btx.send(chunk.to_vec()).ok();
        }
        drop(btx); // disconnect -> workers drain and exit

        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut latency = Histogram::new();
    let mut batch_sizes = 0u64;
    for t in tallies {
        let t = t?;
        report.completed += t.completed;
        report.batches += t.batches;
        batch_sizes += t.batch_sizes;
        latency.merge(&t.latency);
    }
    let wall = t_start.elapsed().as_secs_f64();
    report.throughput_per_s = report.completed as f64 / wall.max(1e-9);
    report.latency = Some(HistogramSummary::from(&latency));
    report.mean_batch = if report.batches > 0 {
        batch_sizes as f64 / report.batches as f64
    } else {
        0.0
    };
    Ok((report, workers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::MockBackend;

    fn frames(n: u64) -> Vec<Frame> {
        (0..n)
            .map(|id| Frame { id, image: vec![(id % 251) as u8; 16], label: None })
            .collect()
    }

    #[test]
    fn stream_completes_all_frames() {
        let mut be = MockBackend::new(0);
        let cfg = StreamConfig {
            interarrival_us: 100,
            service_us_per_image: 50,
            policy: BatchPolicy { max_batch: 4, max_wait_us: 500, queue_cap: 64 },
        };
        let r = run_stream(frames(100), &mut be, &cfg).unwrap();
        assert_eq!(r.completed + r.rejected, 100);
        assert_eq!(r.completed, be.seen);
        assert!(r.throughput_per_s > 0.0);
    }

    #[test]
    fn fast_arrivals_produce_bigger_batches() {
        let cfg_slow = StreamConfig {
            interarrival_us: 10_000,
            service_us_per_image: 10,
            policy: BatchPolicy { max_batch: 8, max_wait_us: 100, queue_cap: 64 },
        };
        let cfg_fast = StreamConfig { interarrival_us: 1, ..cfg_slow };
        let mut be1 = MockBackend::new(0);
        let r_slow = run_stream(frames(200), &mut be1, &cfg_slow).unwrap();
        let mut be2 = MockBackend::new(0);
        let r_fast = run_stream(frames(200), &mut be2, &cfg_fast).unwrap();
        assert!(
            r_fast.mean_batch > r_slow.mean_batch,
            "fast {} vs slow {}",
            r_fast.mean_batch,
            r_slow.mean_batch
        );
    }

    #[test]
    fn overload_rejects_but_never_loses() {
        let mut be = MockBackend::new(0);
        let cfg = StreamConfig {
            interarrival_us: 1,
            service_us_per_image: 10_000,
            policy: BatchPolicy { max_batch: 2, max_wait_us: 10, queue_cap: 4 },
        };
        let r = run_stream(frames(50), &mut be, &cfg).unwrap();
        assert_eq!(r.completed + r.rejected, 50);
        assert_eq!(r.completed, be.seen);
    }

    #[test]
    fn accuracy_accounting() {
        // MockBackend score = byte sum; classify: score>0 -> class 1
        let mut be = MockBackend::new(0);
        let fr = vec![
            Frame { id: 0, image: vec![1; 4], label: Some(1) },
            Frame { id: 1, image: vec![0; 4], label: Some(0) },
            Frame { id: 2, image: vec![2; 4], label: Some(0) }, // wrong
        ];
        let cfg = StreamConfig {
            interarrival_us: 10,
            service_us_per_image: 1,
            policy: BatchPolicy::default(),
        };
        let r = run_stream(fr, &mut be, &cfg).unwrap();
        assert_eq!(r.labelled, 3);
        assert_eq!(r.correct, 2);
    }

    #[test]
    fn threaded_serving_completes() {
        let be = MockBackend::new(0);
        let (r, be) = serve_threaded(
            frames(64),
            be,
            BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 16 },
        )
        .unwrap();
        assert_eq!(r.completed + r.rejected, 64);
        assert_eq!(r.completed, be.seen);
        assert!(r.latency.unwrap().p99_us > 0);
    }

    #[test]
    fn parallel_serving_conserves_frames() {
        let workers: Vec<MockBackend> = (0..4).map(|_| MockBackend::new(0)).collect();
        let (r, workers) = serve_parallel(
            frames(200),
            workers,
            BatchPolicy { max_batch: 8, max_wait_us: 100, queue_cap: 256 },
        )
        .unwrap();
        assert_eq!(r.completed + r.rejected, 200);
        let seen: u64 = workers.iter().map(|w| w.seen).sum();
        assert_eq!(seen, r.completed);
        assert!(r.throughput_per_s > 0.0);
        assert!(r.latency.is_some());
    }

    #[test]
    fn parallel_serving_rejects_empty_worker_pool() {
        let workers: Vec<MockBackend> = Vec::new();
        assert!(serve_parallel(frames(4), workers, BatchPolicy::default()).is_err());
    }

    #[test]
    fn parallel_serving_single_worker_matches_threaded_totals() {
        let (r, workers) = serve_parallel(
            frames(64),
            vec![MockBackend::new(0)],
            BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 64 },
        )
        .unwrap();
        assert_eq!(r.completed + r.rejected, 64);
        assert_eq!(workers[0].seen, r.completed);
    }

    /// Wraps a real backend and records every (image, scores) pair so
    /// tests can check what the parallel path actually computed.
    struct CaptureBackend<B: Backend> {
        inner: B,
        seen: Vec<(Vec<u8>, Vec<i32>)>,
    }

    impl<B: Backend> Backend for CaptureBackend<B> {
        fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
            let scores = self.inner.infer_batch(images)?;
            for (img, s) in images.iter().zip(&scores) {
                self.seen.push((img.to_vec(), s.clone()));
            }
            Ok(scores)
        }

        fn name(&self) -> &'static str {
            "capture"
        }

        fn max_batch(&self) -> usize {
            self.inner.max_batch()
        }
    }

    #[test]
    fn parallel_batched_serving_is_bit_exact_with_serial_inference() {
        use crate::coordinator::backend::BitplaneBackend;
        use crate::model::weights::random_params;
        use crate::model::zoo::tiny_1cat;
        let np = random_params(&tiny_1cat(), 33);
        let mut rng = crate::util::Rng64::new(7);
        let imgs: Vec<Vec<u8>> = (0..12)
            .map(|_| (0..3072).map(|_| rng.next_u8()).collect())
            .collect();
        let frames: Vec<Frame> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| Frame { id: i as u64, image: im.clone(), label: None })
            .collect();
        let workers: Vec<_> = (0..3)
            .map(|_| CaptureBackend { inner: BitplaneBackend::new(&np).unwrap(), seen: Vec::new() })
            .collect();
        let (r, workers) = serve_parallel(
            frames,
            workers,
            BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 64 },
        )
        .unwrap();
        assert_eq!(r.completed, 12);
        assert_eq!(r.rejected, 0);
        let mut checked = 0usize;
        for w in &workers {
            for (img, scores) in &w.seen {
                assert_eq!(
                    scores,
                    &crate::nn::layers::forward(&np, img).unwrap(),
                    "parallel batch path diverged from serial inference"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, 12, "every frame must be scored exactly once");
    }

    #[test]
    fn prop_stream_conservation() {
        crate::testkit::check(30, |rng| {
            let mut be = MockBackend::new(0);
            let cfg = StreamConfig {
                interarrival_us: 1 + rng.below(1000) as u64,
                service_us_per_image: rng.below(2000) as u64,
                policy: BatchPolicy {
                    max_batch: 1 + rng.below(8) as usize,
                    max_wait_us: rng.below(3000) as u64,
                    queue_cap: 1 + rng.below(32) as usize,
                },
            };
            let n = 1 + rng.below(100) as u64;
            let r = run_stream(frames(n), &mut be, &cfg).unwrap();
            assert_eq!(r.completed + r.rejected, n);
            assert_eq!(r.completed, be.seen);
        });
    }
}
