//! Model registry for the multi-model serving gateway: named models,
//! each bound to an inference engine ([`BackendKind`]) and a worker
//! count, instantiated into per-worker backend pools.
//!
//! Spec syntax (CLI `serve --models`): a comma-separated list of
//! `name:backend[:workers]`, e.g. `1cat:bitplane,10cat:opt:2`. Workers
//! default to 1; the overlay backend is single-frame (the MDP has one
//! camera and one scratchpad image slot), so overlay pools of any size
//! still serve one frame per worker at a time.

use std::collections::HashMap;

use super::backend::{Backend, BitplaneBackend, GoldenBackend, OptBackend, OverlayBackend};
use crate::compiler::lower::{compile, InputMode};
use crate::model::NetParams;
use crate::util::TinError;
use crate::Result;

/// Which inference engine a model is served on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// `nn::layers` — the oracle, slow by design.
    Golden,
    /// `nn::opt` — the bit-packed fast engine.
    Opt,
    /// `nn::bitplane` — the popcount engine (fastest CPU path).
    Bitplane,
    /// The cycle-accurate overlay simulator.
    Overlay,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "golden" => Ok(BackendKind::Golden),
            "opt" => Ok(BackendKind::Opt),
            "bitplane" => Ok(BackendKind::Bitplane),
            "overlay" => Ok(BackendKind::Overlay),
            other => Err(TinError::Config(format!(
                "unknown backend '{other}' (expected golden|opt|bitplane|overlay)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Golden => "golden",
            BackendKind::Opt => "opt",
            BackendKind::Bitplane => "bitplane",
            BackendKind::Overlay => "overlay",
        }
    }
}

/// One parsed `name:backend[:workers]` entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub backend: BackendKind,
    pub workers: usize,
}

/// Parse a `--models` spec list: `name:backend[:workers],...`.
pub fn parse_model_specs(s: &str) -> Result<Vec<ModelSpec>> {
    let mut specs: Vec<ModelSpec> = Vec::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 2 || fields.len() > 3 || fields[0].is_empty() {
            return Err(TinError::Config(format!(
                "bad model spec '{part}' (expected name:backend[:workers])"
            )));
        }
        let backend = BackendKind::parse(fields[1])?;
        let workers = match fields.get(2) {
            Some(w) => w
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| TinError::Config(format!("bad worker count in '{part}'")))?,
            None => 1,
        };
        let name = fields[0].to_string();
        if specs.iter().any(|sp| sp.name == name) {
            return Err(TinError::Config(format!("duplicate model name '{name}'")));
        }
        specs.push(ModelSpec { name, backend, workers });
    }
    if specs.is_empty() {
        return Err(TinError::Config("empty --models spec".into()));
    }
    Ok(specs)
}

/// A concrete backend instance behind one enum, so heterogeneous worker
/// pools (`Vec<AnyBackend>`) stay `Send` without trait objects.
pub enum AnyBackend {
    Golden(GoldenBackend),
    Opt(OptBackend),
    Bitplane(BitplaneBackend),
    Overlay(Box<OverlayBackend>),
}

impl Backend for AnyBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        match self {
            AnyBackend::Golden(b) => b.infer_batch(images),
            AnyBackend::Opt(b) => b.infer_batch(images),
            AnyBackend::Bitplane(b) => b.infer_batch(images),
            AnyBackend::Overlay(b) => b.infer_batch(images),
        }
    }

    fn infer_batch_into(&mut self, images: &[&[u8]], out: &mut Vec<Vec<i32>>) -> Result<()> {
        match self {
            AnyBackend::Golden(b) => b.infer_batch_into(images, out),
            AnyBackend::Opt(b) => b.infer_batch_into(images, out),
            AnyBackend::Bitplane(b) => b.infer_batch_into(images, out),
            AnyBackend::Overlay(b) => b.infer_batch_into(images, out),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            AnyBackend::Golden(b) => b.name(),
            AnyBackend::Opt(b) => b.name(),
            AnyBackend::Bitplane(b) => b.name(),
            AnyBackend::Overlay(b) => b.name(),
        }
    }

    fn max_batch(&self) -> usize {
        match self {
            AnyBackend::Golden(b) => b.max_batch(),
            AnyBackend::Opt(b) => b.max_batch(),
            AnyBackend::Bitplane(b) => b.max_batch(),
            AnyBackend::Overlay(b) => b.max_batch(),
        }
    }

    fn input_len(&self) -> Option<usize> {
        match self {
            AnyBackend::Golden(b) => b.input_len(),
            AnyBackend::Opt(b) => b.input_len(),
            AnyBackend::Bitplane(b) => b.input_len(),
            AnyBackend::Overlay(b) => b.input_len(),
        }
    }
}

/// One registered model: its spec plus the trained (or synthetic)
/// parameters it serves.
pub struct ModelEntry {
    pub spec: ModelSpec,
    pub params: NetParams,
}

/// Named models bound to engines — the gateway's front-door inventory.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
    by_name: HashMap<String, usize>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry::default()
    }

    /// Register a model; names must be unique.
    pub fn register(&mut self, spec: ModelSpec, params: NetParams) -> Result<()> {
        if self.by_name.contains_key(&spec.name) {
            return Err(TinError::Config(format!("model '{}' already registered", spec.name)));
        }
        self.by_name.insert(spec.name.clone(), self.entries.len());
        self.entries.push(ModelEntry { spec, params });
        Ok(())
    }

    /// Hot-swap the parameters behind an existing name (freshly trained
    /// weights replacing the ones a lane was built from). Pools built
    /// before the swap keep serving the old params; rebuild via
    /// [`ModelRegistry::build_pool`] to pick up the new ones.
    pub fn replace(&mut self, name: &str, params: NetParams) -> Result<()> {
        match self.by_name.get(name) {
            Some(&i) => {
                self.entries[i].params = params;
                Ok(())
            }
            None => Err(TinError::Config(format!(
                "cannot replace unknown model '{name}'"
            ))),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Instantiate the per-worker backend pool for one entry. Each
    /// worker owns its engine instance (and scratch arena), so a pool
    /// scales across cores exactly like `serve_parallel` workers.
    pub fn build_pool(&self, entry: &ModelEntry) -> Result<Vec<AnyBackend>> {
        let n = entry.spec.workers.max(1);
        (0..n)
            .map(|_| -> Result<AnyBackend> {
                Ok(match entry.spec.backend {
                    BackendKind::Golden => AnyBackend::Golden(GoldenBackend::new(&entry.params)),
                    BackendKind::Opt => AnyBackend::Opt(OptBackend::new(&entry.params)?),
                    BackendKind::Bitplane => {
                        AnyBackend::Bitplane(BitplaneBackend::new(&entry.params)?)
                    }
                    BackendKind::Overlay => {
                        let compiled = compile(&entry.params, InputMode::Direct)?;
                        AnyBackend::Overlay(Box::new(OverlayBackend::new(compiled)))
                    }
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::random_params;
    use crate::model::zoo::{reduced_10cat, tiny_1cat};

    #[test]
    fn parses_spec_list() {
        let specs = parse_model_specs("1cat:bitplane,10cat:opt:2").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0], ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 1 });
        assert_eq!(specs[1], ModelSpec { name: "10cat".into(), backend: BackendKind::Opt, workers: 2 });
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(parse_model_specs("").is_err());
        assert!(parse_model_specs("1cat").is_err());
        assert!(parse_model_specs("1cat:warp").is_err());
        assert!(parse_model_specs("1cat:opt:0").is_err());
        assert!(parse_model_specs("1cat:opt:x").is_err());
        assert!(parse_model_specs(":opt").is_err());
        assert!(parse_model_specs("a:opt,a:bitplane").is_err(), "duplicate names");
    }

    #[test]
    fn registry_builds_pools_on_every_backend() {
        let np1 = random_params(&tiny_1cat(), 41);
        let np10 = random_params(&reduced_10cat(), 42);
        let mut reg = ModelRegistry::new();
        for (name, backend, np) in [
            ("g", BackendKind::Golden, &np1),
            ("o", BackendKind::Opt, &np1),
            ("b", BackendKind::Bitplane, &np10),
            ("v", BackendKind::Overlay, &np1),
        ] {
            reg.register(
                ModelSpec { name: name.into(), backend, workers: 2 },
                np.clone(),
            )
            .unwrap();
        }
        assert_eq!(reg.len(), 4);
        let mut rng = crate::util::Rng64::new(6);
        let img: Vec<u8> = (0..3072).map(|_| rng.next_u8()).collect();
        for entry in reg.entries() {
            let mut pool = reg.build_pool(entry).unwrap();
            assert_eq!(pool.len(), 2);
            let golden = crate::nn::layers::forward(&entry.params, &img).unwrap();
            for be in pool.iter_mut() {
                let out = be.infer_batch(&[&img]).unwrap();
                assert_eq!(out[0], golden, "{} on {}", entry.spec.name, be.name());
            }
        }
    }

    #[test]
    fn registry_rejects_duplicate_names() {
        let np = random_params(&tiny_1cat(), 1);
        let mut reg = ModelRegistry::new();
        let spec = ModelSpec { name: "m".into(), backend: BackendKind::Opt, workers: 1 };
        reg.register(spec.clone(), np.clone()).unwrap();
        assert!(reg.register(spec, np).is_err());
        assert!(reg.get("m").is_some());
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn replace_hot_swaps_params_in_place() {
        let np_a = random_params(&tiny_1cat(), 1);
        let np_b = random_params(&tiny_1cat(), 2);
        assert_ne!(np_a.params, np_b.params);
        let mut reg = ModelRegistry::new();
        let spec = ModelSpec { name: "m".into(), backend: BackendKind::Opt, workers: 1 };
        reg.register(spec, np_a).unwrap();
        reg.replace("m", np_b.clone()).unwrap();
        assert_eq!(reg.get("m").unwrap().params.params, np_b.params);
        assert!(reg.replace("ghost", np_b).is_err());
        // pools built after the swap serve the new params
        let entry = reg.get("m").unwrap();
        let mut pool = reg.build_pool(entry).unwrap();
        let mut rng = crate::util::Rng64::new(3);
        let img: Vec<u8> = (0..3072).map(|_| rng.next_u8()).collect();
        let want = crate::nn::layers::forward(&reg.get("m").unwrap().params, &img).unwrap();
        let got = pool[0].infer_batch(&[&img]).unwrap();
        assert_eq!(got[0], want);
    }
}
