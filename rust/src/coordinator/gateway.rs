//! The multi-model serving gateway: one front door, many models, many
//! engines.
//!
//! The paper ships two detectors from one overlay (the 10-category
//! CIFAR classifier and the 1-category person detector); FINN-style
//! serving treats that as a multi-workload scheduling problem. This
//! module is the front door: a [`Router`] admits tagged requests with
//! per-request deadlines and [`Priority`]s, applies a per-model
//! [`BatchPolicy`] (low-priority shedding at half queue occupancy,
//! hard rejection at `queue_cap`, deadline expiry at dispatch), and
//! [`serve_gateway`] drives one sharded worker pool per model — the
//! same scoped-thread, per-worker-scratch, zero-steady-state-allocation
//! scheme as [`crate::coordinator::pipeline::serve_parallel`] — with
//! per-model latency recorded into named `e2e.*` series on a
//! [`crate::obs::MetricsHub`] (injectable via [`GatewayConfig::hub`])
//! and merged into a fleet report.
//!
//! Exact accounting is the contract: for every model and for the fleet,
//! `submitted == completed + rejected + expired` once serving ends
//! (unknown-model requests count as fleet-level rejections). The
//! conservation proptests in this module and the differential tests
//! (gateway scores bit-exact with serial per-model inference) pin it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::backend::Backend;
use super::batcher::{BatchPolicy, Batcher, Priority, Request};
use super::metrics::{Histogram, Meter};
use super::pipeline::HistogramSummary;
use crate::util::TinError;
use crate::Result;

/// One tagged inference request entering the gateway.
#[derive(Clone, Debug)]
pub struct GatewayRequest {
    pub id: u64,
    /// Registered model name; unknown names are rejected on admission.
    pub model: String,
    pub image: Vec<u8>,
    /// Latency budget in microseconds from admission; the request is
    /// dropped (counted `expired`) if it is still queued past the
    /// budget. `None` never expires.
    pub deadline_budget_us: Option<u64>,
    pub priority: Priority,
}

impl GatewayRequest {
    pub fn new(id: u64, model: impl Into<String>, image: Vec<u8>) -> Self {
        GatewayRequest {
            id,
            model: model.into(),
            image,
            deadline_budget_us: None,
            priority: Priority::Normal,
        }
    }

    pub fn with_deadline(mut self, budget_us: u64) -> Self {
        self.deadline_budget_us = Some(budget_us);
        self
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }
}

/// Admission outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    Queued,
    /// Shed by backpressure (queue full, or half-full for low priority).
    Rejected,
    /// No lane with that model name.
    UnknownModel,
}

/// Per-lane exact accounting. Once serving is done,
/// `submitted == completed + rejected + expired`.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneCounts {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
}

struct RouterLane {
    name: String,
    policy: BatchPolicy,
    batcher: Batcher,
    counts: LaneCounts,
}

/// The admission + dispatch state machine (time injected, fully
/// deterministic — the threaded front-end, the network server and the
/// proptests share it).
pub struct Router {
    lanes: Vec<RouterLane>,
    by_name: HashMap<String, usize>,
    /// Requests naming no registered model (fleet-level rejections).
    pub unknown_model: u64,
    /// When set, `(lane, request id)` pairs dropped as expired are
    /// appended to a log drained via [`Router::take_expired`] — the
    /// network front-end needs them to answer each expired request on
    /// the wire. Off by default so long-lived in-process callers that
    /// never drain the log don't grow it unboundedly.
    pub log_expired: bool,
    expired_log: Vec<(usize, u64)>,
}

impl Router {
    /// Build a router with one lane per (model name, policy).
    pub fn new(lanes: &[(String, BatchPolicy)]) -> Self {
        let mut by_name = HashMap::new();
        let lanes: Vec<RouterLane> = lanes
            .iter()
            .enumerate()
            .map(|(i, (name, policy))| {
                by_name.insert(name.clone(), i);
                RouterLane {
                    name: name.clone(),
                    policy: *policy,
                    batcher: Batcher::new(*policy),
                    counts: LaneCounts::default(),
                }
            })
            .collect();
        Router { lanes, by_name, unknown_model: 0, log_expired: false, expired_log: Vec::new() }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn lane_name(&self, li: usize) -> &str {
        &self.lanes[li].name
    }

    pub fn counts(&self, li: usize) -> LaneCounts {
        self.lanes[li].counts
    }

    /// Admit one request at time `now_us`: route by model tag, stamp the
    /// absolute deadline, push through the lane's batcher (which sheds
    /// low-priority work at half occupancy).
    pub fn admit(&mut self, gr: GatewayRequest, now_us: u64) -> Admit {
        let Some(&li) = self.by_name.get(&gr.model) else {
            self.unknown_model += 1;
            return Admit::UnknownModel;
        };
        let lane = &mut self.lanes[li];
        lane.counts.submitted += 1;
        let req = Request {
            deadline_us: gr.deadline_budget_us.map(|b| now_us.saturating_add(b)),
            priority: gr.priority,
            ..Request::new(gr.id, now_us, gr.image)
        };
        if lane.batcher.push(req) {
            Admit::Queued
        } else {
            lane.counts.rejected += 1;
            Admit::Rejected
        }
    }

    /// Pop every batch whose lane policy fires at `now_us`. Requests past
    /// their deadline are dropped here (counted `expired`); only live
    /// batches are returned, tagged with their lane index.
    pub fn poll(&mut self, now_us: u64) -> Vec<(usize, Vec<Request>)> {
        let mut out = Vec::new();
        for (li, lane) in self.lanes.iter_mut().enumerate() {
            while let Some(batch) = lane.batcher.poll(now_us) {
                let mut live = Vec::with_capacity(batch.len());
                for r in batch {
                    if r.expired(now_us) {
                        lane.counts.expired += 1;
                        if self.log_expired {
                            self.expired_log.push((li, r.id));
                        }
                    } else {
                        live.push(r);
                    }
                }
                if !live.is_empty() {
                    out.push((li, live));
                }
            }
        }
        out
    }

    /// Drain every lane (shutdown), chunking by each lane's `max_batch`
    /// and applying the same deadline expiry as [`Router::poll`].
    pub fn flush(&mut self, now_us: u64) -> Vec<(usize, Vec<Request>)> {
        let mut out = Vec::new();
        for (li, lane) in self.lanes.iter_mut().enumerate() {
            let mut live = Vec::new();
            for r in lane.batcher.flush() {
                if r.expired(now_us) {
                    lane.counts.expired += 1;
                    if self.log_expired {
                        self.expired_log.push((li, r.id));
                    }
                } else {
                    live.push(r);
                }
            }
            for chunk in live.chunks(lane.policy.max_batch.max(1)) {
                out.push((li, chunk.to_vec()));
            }
        }
        out
    }

    /// Record `n` completions on a lane (called by whoever ran the
    /// dispatched batch).
    pub fn note_completed(&mut self, li: usize, n: u64) {
        self.lanes[li].counts.completed += n;
    }

    /// Record `n` post-admission rejections on a lane — the network
    /// server's escape hatch when a dispatched batch fails in a worker
    /// (every admitted request must still leave the ledger exactly once).
    pub fn note_rejected(&mut self, li: usize, n: u64) {
        self.lanes[li].counts.rejected += n;
    }

    /// Drain the `(lane, request id)` expiry log (see
    /// [`Router::log_expired`]). Empty unless logging is enabled.
    pub fn take_expired(&mut self) -> Vec<(usize, u64)> {
        std::mem::take(&mut self.expired_log)
    }
}

/// A cross-thread stop signal for [`serve_gateway`] (and the network
/// front-end built on it): once [`DrainHandle::drain`] fires, the
/// gateway stops admitting new work, flushes what is queued, answers
/// everything in flight, and returns with exact accounting intact.
#[derive(Clone, Debug, Default)]
pub struct DrainHandle(Arc<AtomicBool>);

impl DrainHandle {
    pub fn new() -> Self {
        DrainHandle::default()
    }

    /// Request a graceful drain (idempotent, callable from any thread).
    pub fn drain(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One model lane handed to [`serve_gateway`]: a name, a batching
/// policy, and a sharded worker pool (one backend instance per worker).
pub struct GatewayLane<B> {
    pub name: String,
    pub policy: BatchPolicy,
    pub workers: Vec<B>,
}

/// Gateway serving knobs.
#[derive(Clone, Default)]
pub struct GatewayConfig {
    /// Collect `(request id, scores)` pairs per model — the hook the
    /// differential tests use to pin gateway results against serial
    /// inference. Off for throughput runs.
    pub collect_scores: bool,
    /// Optional stop signal: once drained, the producer stops admitting
    /// the rest of the workload (never-admitted requests are simply not
    /// counted), flushes the queues, and the report stays conserved.
    pub drain: Option<DrainHandle>,
    /// Optional telemetry hub: when set, the gateway registers its
    /// per-model series (`model.*` counters, `e2e.*` histograms) there
    /// so an embedding caller can snapshot them live; otherwise a
    /// private hub backs the same series for the report alone.
    pub hub: Option<Arc<crate::obs::MetricsHub>>,
}

/// Per-model serving results.
pub struct ModelReport {
    pub name: String,
    pub backend: &'static str,
    pub workers: usize,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency: HistogramSummary,
    pub throughput_per_s: f64,
    /// `(request id, scores)` for every completed request, when
    /// [`GatewayConfig::collect_scores`] is set.
    pub scores: Vec<(u64, Vec<i32>)>,
}

impl ModelReport {
    /// One aligned per-model line, shared by every serving CLI
    /// (`serve --models`, `serve --listen`, replica logs) so the
    /// formats can't drift apart.
    pub fn summary_line(&self) -> String {
        format!(
            "  {:8} on {:12} x{}: {:>5} done / {:>3} rej / {:>3} exp, mean batch {:.2}, p50 {}us p99 {}us, {:.0} fps",
            self.name,
            self.backend,
            self.workers,
            self.completed,
            self.rejected,
            self.expired,
            self.mean_batch,
            self.latency.p50_us,
            self.latency.p99_us,
            self.throughput_per_s
        )
    }
}

/// The merged fleet report.
pub struct GatewayReport {
    pub models: Vec<ModelReport>,
    pub submitted: u64,
    pub completed: u64,
    /// Includes per-lane backpressure rejections AND unknown-model
    /// requests (tracked separately in `unknown_model`).
    pub rejected: u64,
    pub expired: u64,
    pub unknown_model: u64,
    pub latency: HistogramSummary,
    pub throughput_per_s: f64,
    pub wall_s: f64,
    /// Wire-layer response ledger (zero for in-process serving): every
    /// response the network server settled — enqueued for a connection,
    /// including busy/ping/reserved-id answers outside the gateway
    /// request ledger above.
    pub settled_responses: u64,
    /// Settled responses actually handed to a connection's outbox.
    pub answered_responses: u64,
    /// Settled responses dropped because the connection's outbox/writer
    /// queue was full or the connection was already gone. Nonzero means
    /// a client flooded past its backpressure budget — accounted, never
    /// silent.
    pub dropped_responses: u64,
    /// The worst-N end-to-end requests with full per-stage stamps
    /// (admitted → enqueued → dispatched → infer → serialized → flushed),
    /// slowest first — dumped from the network server's slow-request
    /// ring at drain. Empty for in-process serving, which has no wire
    /// stages to stamp.
    pub slow_traces: Vec<crate::obs::StageTrace>,
}

impl GatewayReport {
    /// The exact-accounting invariant, per model and fleet-wide, plus
    /// the wire-layer response ledger (answered + dropped == settled).
    pub fn conserved(&self) -> bool {
        self.submitted == self.completed + self.rejected + self.expired
            && self.settled_responses == self.answered_responses + self.dropped_responses
            && self
                .models
                .iter()
                .all(|m| m.submitted == m.completed + m.rejected + m.expired)
    }

    /// The fleet header line, with a caller-chosen verb ("gateway",
    /// "gateway drained") — shared by the serving CLIs.
    pub fn summary_line(&self, label: &str) -> String {
        let mut line = format!(
            "{label}: {} submitted, {} completed, {} rejected ({} unknown-model), {} expired in {:.2} s -> {:.0} fps fleet-wide",
            self.submitted,
            self.completed,
            self.rejected,
            self.unknown_model,
            self.expired,
            self.wall_s,
            self.throughput_per_s
        );
        if self.settled_responses > 0 {
            line.push_str(&format!(
                "; wire: {} settled = {} answered + {} dropped",
                self.settled_responses, self.answered_responses, self.dropped_responses
            ));
        }
        line
    }
}

/// Serve a tagged request stream across per-model worker pools.
///
/// The producer thread admits requests through the [`Router`] and
/// dispatches live batches onto one bounded channel per model; each
/// worker owns its backend and a reusable score buffer
/// ([`Backend::infer_batch_into`]), so CPU-engine lanes run with zero
/// steady-state allocations. Distinct models genuinely run
/// concurrently: every worker of every lane is its own OS thread.
pub fn serve_gateway<B: Backend + Send>(
    requests: Vec<GatewayRequest>,
    mut lanes: Vec<GatewayLane<B>>,
    cfg: &GatewayConfig,
) -> Result<(GatewayReport, Vec<GatewayLane<B>>)> {
    use std::sync::mpsc::sync_channel;
    use std::sync::Mutex;

    if lanes.is_empty() {
        return Err(TinError::Config("serve_gateway needs >= 1 model lane".into()));
    }
    for lane in &lanes {
        if lane.workers.is_empty() {
            return Err(TinError::Config(format!(
                "model '{}' has an empty worker pool",
                lane.name
            )));
        }
    }

    // effective per-lane policy: never hand a backend more than its
    // max_batch (the overlay takes one frame at a time)
    let routes: Vec<(String, BatchPolicy)> = lanes
        .iter()
        .map(|l| {
            let eff = BatchPolicy {
                max_batch: l.policy.max_batch.min(l.workers[0].max_batch()).max(1),
                ..l.policy
            };
            (l.name.clone(), eff)
        })
        .collect();
    let mut router = Router::new(&routes);

    // every latency sample lands in a named hub series (shared with the
    // caller's hub when one is injected), not a per-worker Histogram —
    // the report below reads the same cells a live snapshot would
    let hub = cfg.hub.clone().unwrap_or_else(|| Arc::new(crate::obs::MetricsHub::new()));
    let lane_e2e: Vec<crate::obs::HistHandle> =
        lanes.iter().map(|l| hub.hist(&format!("e2e.{}", l.name))).collect();

    struct WorkerTally {
        completed: u64,
        batches: u64,
        batch_sizes: u64,
        meter: Meter,
        scores: Vec<(u64, Vec<i32>)>,
    }

    let n_lanes = lanes.len();
    let mut txs = Vec::with_capacity(n_lanes);
    let mut rxs = Vec::with_capacity(n_lanes);
    for lane in &lanes {
        let (tx, rx) = sync_channel::<Vec<Request>>(2 * lane.workers.len());
        txs.push(tx);
        rxs.push(Mutex::new(rx));
    }
    let rxs = &rxs;
    let t_start = std::time::Instant::now();
    let collect_scores = cfg.collect_scores;

    let tallies: Vec<(usize, Result<WorkerTally>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (li, lane) in lanes.iter_mut().enumerate() {
            for be in lane.workers.iter_mut() {
                let e2e = lane_e2e[li].clone();
                handles.push((
                    li,
                    s.spawn(move || -> Result<WorkerTally> {
                        let mut tally = WorkerTally {
                            completed: 0,
                            batches: 0,
                            batch_sizes: 0,
                            meter: Meter::default(),
                            scores: Vec::new(),
                        };
                        let mut failed: Option<TinError> = None;
                        let mut scores_buf: Vec<Vec<i32>> = Vec::new();
                        loop {
                            // hold the lane lock only for the dequeue
                            let batch = match rxs[li].lock().unwrap().recv() {
                                Ok(b) => b,
                                Err(_) => break, // producer done
                            };
                            if failed.is_some() {
                                continue; // drain so the producer never blocks
                            }
                            let imgs: Vec<&[u8]> =
                                batch.iter().map(|r| r.image.as_slice()).collect();
                            match be.infer_batch_into(&imgs, &mut scores_buf) {
                                Ok(()) => {
                                    let t = t_start.elapsed().as_micros() as u64;
                                    for (req, sc) in batch.iter().zip(scores_buf.iter()) {
                                        e2e.record(t.saturating_sub(req.enqueue_us));
                                        tally.completed += 1;
                                        if collect_scores {
                                            tally.scores.push((req.id, sc.clone()));
                                        }
                                    }
                                    tally.meter.record(t, batch.len() as u64);
                                    tally.batches += 1;
                                    tally.batch_sizes += batch.len() as u64;
                                }
                                Err(e) => failed = Some(e),
                            }
                        }
                        match failed {
                            Some(e) => Err(e),
                            None => Ok(tally),
                        }
                    }),
                ));
            }
        }

        // front door: admit, batch, expire, dispatch
        for gr in requests {
            if let Some(d) = &cfg.drain {
                if d.is_draining() {
                    break; // stop admitting; fall through to the flush
                }
            }
            let now = t_start.elapsed().as_micros() as u64;
            router.admit(gr, now);
            for (li, batch) in router.poll(t_start.elapsed().as_micros() as u64) {
                txs[li].send(batch).ok();
            }
        }
        let now = t_start.elapsed().as_micros() as u64;
        for (li, batch) in router.flush(now) {
            txs[li].send(batch).ok();
        }
        drop(txs); // disconnect -> workers drain and exit

        handles
            .into_iter()
            .map(|(li, h)| (li, h.join().unwrap()))
            .collect()
    });

    // merge per-worker tallies into per-model and fleet reports
    struct LaneAgg {
        completed: u64,
        batches: u64,
        batch_sizes: u64,
        meter: Meter,
        scores: Vec<(u64, Vec<i32>)>,
    }
    let mut aggs: Vec<LaneAgg> = (0..n_lanes)
        .map(|_| LaneAgg {
            completed: 0,
            batches: 0,
            batch_sizes: 0,
            meter: Meter::default(),
            scores: Vec::new(),
        })
        .collect();
    for (li, tally) in tallies {
        let t = tally?;
        let agg = &mut aggs[li];
        agg.completed += t.completed;
        agg.batches += t.batches;
        agg.batch_sizes += t.batch_sizes;
        agg.meter.merge(&t.meter);
        agg.scores.extend(t.scores);
    }

    let wall_s = t_start.elapsed().as_secs_f64();
    let mut fleet_latency = Histogram::new();
    let mut models = Vec::with_capacity(n_lanes);
    let mut submitted = router.unknown_model;
    let mut completed = 0u64;
    let mut rejected = router.unknown_model;
    let mut expired = 0u64;
    for (li, (lane, agg)) in lanes.iter().zip(aggs.into_iter()).enumerate() {
        router.note_completed(li, agg.completed);
        let c = router.counts(li);
        submitted += c.submitted;
        completed += c.completed;
        rejected += c.rejected;
        expired += c.expired;
        // mirror the settled ledger into the hub's per-model counters so
        // an injected hub can be snapshotted by the embedding caller
        hub.counter(&format!("model.{}.submitted", lane.name)).add(c.submitted);
        hub.counter(&format!("model.{}.completed", lane.name)).add(c.completed);
        hub.counter(&format!("model.{}.rejected", lane.name)).add(c.rejected);
        hub.counter(&format!("model.{}.expired", lane.name)).add(c.expired);
        let lane_hist = lane_e2e[li].snap().to_histogram();
        fleet_latency.merge(&lane_hist);
        models.push(ModelReport {
            name: lane.name.clone(),
            backend: lane.workers[0].name(),
            workers: lane.workers.len(),
            submitted: c.submitted,
            completed: c.completed,
            rejected: c.rejected,
            expired: c.expired,
            batches: agg.batches,
            mean_batch: if agg.batches > 0 {
                agg.batch_sizes as f64 / agg.batches as f64
            } else {
                0.0
            },
            latency: HistogramSummary::from(&lane_hist),
            throughput_per_s: agg.meter.per_second(),
            scores: agg.scores,
        });
    }

    let report = GatewayReport {
        models,
        submitted,
        completed,
        rejected,
        expired,
        unknown_model: router.unknown_model,
        latency: HistogramSummary::from(&fleet_latency),
        throughput_per_s: completed as f64 / wall_s.max(1e-9),
        wall_s,
        settled_responses: 0,
        answered_responses: 0,
        dropped_responses: 0,
        slow_traces: Vec::new(),
    };
    Ok((report, lanes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{BitplaneBackend, MockBackend, OptBackend};
    use crate::model::weights::random_params;
    use crate::model::zoo::{reduced_10cat, tiny_1cat};
    use crate::util::Rng64;

    fn mock_lane(name: &str, workers: usize, policy: BatchPolicy) -> GatewayLane<MockBackend> {
        GatewayLane {
            name: name.into(),
            policy,
            workers: (0..workers).map(|_| MockBackend::new(0)).collect(),
        }
    }

    fn wide_policy() -> BatchPolicy {
        BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 10_000 }
    }

    #[test]
    fn gateway_serves_two_models_bit_exact_with_serial_inference() {
        // the acceptance-criterion test: two models on two distinct
        // engines, concurrently, scores bit-exact with serial inference
        let np1 = random_params(&tiny_1cat(), 51);
        let np10 = random_params(&reduced_10cat(), 52);
        let mut rng = Rng64::new(8);
        let imgs: Vec<Vec<u8>> = (0..24)
            .map(|_| (0..3072).map(|_| rng.next_u8()).collect())
            .collect();
        let requests: Vec<GatewayRequest> = imgs
            .iter()
            .enumerate()
            .map(|(i, im)| {
                let model = if i % 2 == 0 { "1cat" } else { "10cat" };
                GatewayRequest::new(i as u64, model, im.clone())
            })
            .collect();
        let lanes = vec![
            GatewayLane {
                name: "1cat".into(),
                policy: wide_policy(),
                workers: (0..2)
                    .map(|_| crate::coordinator::registry::AnyBackend::Bitplane(
                        BitplaneBackend::new(&np1).unwrap(),
                    ))
                    .collect(),
            },
            GatewayLane {
                name: "10cat".into(),
                policy: wide_policy(),
                workers: (0..2)
                    .map(|_| crate::coordinator::registry::AnyBackend::Opt(
                        OptBackend::new(&np10).unwrap(),
                    ))
                    .collect(),
            },
        ];
        let (report, _lanes) =
            serve_gateway(
                requests,
                lanes,
                &GatewayConfig { collect_scores: true, ..Default::default() },
            )
            .unwrap();
        assert!(report.conserved(), "accounting broken");
        assert_eq!(report.completed, 24);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.expired, 0);
        let mut seen = 0usize;
        for m in &report.models {
            let np = if m.name == "1cat" { &np1 } else { &np10 };
            assert_eq!(m.completed as usize, 12);
            assert_eq!(m.scores.len(), 12);
            for (id, scores) in &m.scores {
                let want = crate::nn::layers::forward(np, &imgs[*id as usize]).unwrap();
                assert_eq!(scores, &want, "model {} request {id} diverged", m.name);
                seen += 1;
            }
        }
        assert_eq!(seen, 24, "every request scored exactly once");
        assert!(report.latency.p99_us > 0);
        assert!(report.throughput_per_s > 0.0);
    }

    #[test]
    fn unknown_model_is_rejected_with_exact_accounting() {
        let requests = vec![
            GatewayRequest::new(0, "known", vec![1; 8]),
            GatewayRequest::new(1, "nope", vec![2; 8]),
            GatewayRequest::new(2, "known", vec![3; 8]),
        ];
        let lanes = vec![mock_lane("known", 1, wide_policy())];
        let (report, lanes) = serve_gateway(requests, lanes, &GatewayConfig::default()).unwrap();
        assert_eq!(report.submitted, 3);
        assert_eq!(report.unknown_model, 1);
        assert_eq!(report.completed, 2);
        assert_eq!(report.rejected, 1); // the unknown-model request
        assert!(report.conserved());
        assert_eq!(lanes[0].workers[0].seen, 2);
    }

    #[test]
    fn gateway_rejects_empty_configurations() {
        let none: Vec<GatewayLane<MockBackend>> = Vec::new();
        assert!(serve_gateway(vec![], none, &GatewayConfig::default()).is_err());
        let empty_pool = vec![GatewayLane::<MockBackend> {
            name: "m".into(),
            policy: wide_policy(),
            workers: Vec::new(),
        }];
        assert!(serve_gateway(vec![], empty_pool, &GatewayConfig::default()).is_err());
    }

    #[test]
    fn router_expires_overdue_requests_deterministically() {
        let policy = BatchPolicy { max_batch: 4, max_wait_us: 1000, queue_cap: 16 };
        let mut router = Router::new(&[("m".to_string(), policy)]);
        // two requests at t=0: one with a 100us budget, one without
        assert_eq!(
            router.admit(GatewayRequest::new(0, "m", vec![]).with_deadline(100), 0),
            Admit::Queued
        );
        assert_eq!(router.admit(GatewayRequest::new(1, "m", vec![]), 0), Admit::Queued);
        // nothing fires before the wait bound
        assert!(router.poll(500).is_empty());
        // at t=1000 the lane fires; request 0 is 900us past its deadline
        let batches = router.poll(1000);
        assert_eq!(batches.len(), 1);
        let (li, batch) = &batches[0];
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
        router.note_completed(*li, 1);
        let c = router.counts(0);
        assert_eq!(c.submitted, 2);
        assert_eq!(c.expired, 1);
        assert_eq!(c.completed, 1);
        assert_eq!(c.completed + c.rejected + c.expired, c.submitted);
    }

    #[test]
    fn router_sheds_low_priority_under_load() {
        let policy = BatchPolicy { max_batch: 64, max_wait_us: u64::MAX, queue_cap: 8 };
        let mut router = Router::new(&[("m".to_string(), policy)]);
        for i in 0..4 {
            assert_eq!(router.admit(GatewayRequest::new(i, "m", vec![]), 0), Admit::Queued);
        }
        // half full: low is shed, normal still admitted
        assert_eq!(
            router.admit(
                GatewayRequest::new(90, "m", vec![]).with_priority(Priority::Low),
                0
            ),
            Admit::Rejected
        );
        assert_eq!(router.admit(GatewayRequest::new(91, "m", vec![]), 0), Admit::Queued);
        let c = router.counts(0);
        assert_eq!(c.rejected, 1);
        assert_eq!(c.submitted, 6);
    }

    #[test]
    fn deadline_exactly_at_dispatch_is_expired_not_completed() {
        // the boundary contract: a request dispatched at the very
        // microsecond its budget runs out has nothing left to spend —
        // it must be counted expired, never served
        let policy = BatchPolicy { max_batch: 8, max_wait_us: 0, queue_cap: 8 };
        let mut router = Router::new(&[("m".to_string(), policy)]);
        assert_eq!(
            router.admit(GatewayRequest::new(0, "m", vec![]).with_deadline(100), 0),
            Admit::Queued
        );
        assert!(router.poll(100).is_empty(), "at-deadline dispatch must expire");
        let c = router.counts(0);
        assert_eq!(c.expired, 1);
        assert_eq!(c.completed, 0);
        assert_eq!(c.submitted, c.completed + c.rejected + c.expired);
        // one microsecond earlier the same request is still live
        let mut router = Router::new(&[("m".to_string(), policy)]);
        router.admit(GatewayRequest::new(1, "m", vec![]).with_deadline(100), 0);
        let batches = router.poll(99);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].1[0].id, 1);
        // a zero budget is expired on the spot
        let mut router = Router::new(&[("m".to_string(), policy)]);
        router.admit(GatewayRequest::new(2, "m", vec![]).with_deadline(0), 50);
        assert!(router.poll(50).is_empty());
        assert_eq!(router.counts(0).expired, 1);
    }

    #[test]
    fn expired_log_reports_dropped_ids_only_when_enabled() {
        let policy = BatchPolicy { max_batch: 8, max_wait_us: 0, queue_cap: 8 };
        let mut router = Router::new(&[("m".to_string(), policy)]);
        router.admit(GatewayRequest::new(7, "m", vec![]).with_deadline(10), 0);
        router.poll(10);
        assert!(router.take_expired().is_empty(), "log off by default");
        router.log_expired = true;
        router.admit(GatewayRequest::new(8, "m", vec![]).with_deadline(10), 100);
        router.admit(GatewayRequest::new(9, "m", vec![]), 100);
        let batches = router.poll(110);
        assert_eq!(batches.len(), 1, "the live request still dispatches");
        assert_eq!(router.take_expired(), vec![(0, 8)]);
        assert!(router.take_expired().is_empty(), "take drains the log");
        // flush logs too
        router.admit(GatewayRequest::new(10, "m", vec![]).with_deadline(5), 200);
        let _ = router.flush(300);
        assert_eq!(router.take_expired(), vec![(0, 10)]);
    }

    #[test]
    fn mid_stream_drain_keeps_exact_accounting() {
        // the satellite contract: a drain fired mid-workload stops
        // admission, flushes the queues, and the ledger still balances
        // exactly (submitted == completed + rejected + expired)
        let n = 400u64;
        let requests: Vec<GatewayRequest> =
            (0..n).map(|id| GatewayRequest::new(id, "m", vec![(id % 251) as u8; 8])).collect();
        let lanes = vec![GatewayLane {
            name: "m".into(),
            policy: BatchPolicy { max_batch: 4, max_wait_us: 0, queue_cap: 64 },
            // 2ms per image: the full workload would take ~800ms, so a
            // 10ms drain reliably lands mid-stream even on a loaded box
            workers: vec![MockBackend::new(2_000)],
        }];
        let handle = DrainHandle::new();
        assert!(!handle.is_draining());
        let cfg =
            GatewayConfig { collect_scores: false, drain: Some(handle.clone()), hub: None };
        let trigger = handle.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            trigger.drain();
        });
        let (report, lanes) = serve_gateway(requests, lanes, &cfg).unwrap();
        t.join().unwrap();
        assert!(report.conserved(), "mid-stream drain broke the ledger");
        assert!(report.submitted < n, "drain should cut admission short (submitted {})", report.submitted);
        assert!(report.completed > 0, "work admitted before the drain still completes");
        assert_eq!(report.completed, lanes[0].workers.iter().map(|w| w.seen).sum::<u64>());
    }

    #[test]
    fn pre_drained_gateway_admits_nothing_and_stays_conserved() {
        let handle = DrainHandle::new();
        handle.drain();
        let requests: Vec<GatewayRequest> =
            (0..16).map(|id| GatewayRequest::new(id, "m", vec![1; 8])).collect();
        let lanes = vec![mock_lane("m", 1, wide_policy())];
        let cfg = GatewayConfig { collect_scores: false, drain: Some(handle), hub: None };
        let (report, _lanes) = serve_gateway(requests, lanes, &cfg).unwrap();
        assert_eq!(report.submitted, 0);
        assert_eq!(report.completed, 0);
        assert!(report.conserved());
    }

    #[test]
    fn prop_router_conservation_under_random_traffic() {
        // random lanes x arrivals x deadlines x priorities: every admitted
        // request leaves exactly once (dispatched live, rejected, or
        // expired) and the ledger balances
        crate::testkit::check(60, |rng| {
            let n_lanes = 1 + rng.below(3) as usize;
            let routes: Vec<(String, BatchPolicy)> = (0..n_lanes)
                .map(|i| {
                    (
                        format!("m{i}"),
                        BatchPolicy {
                            max_batch: 1 + rng.below(6) as usize,
                            max_wait_us: rng.below(2000) as u64,
                            queue_cap: 1 + rng.below(24) as usize,
                        },
                    )
                })
                .collect();
            let mut router = Router::new(&routes);
            let mut now = 0u64;
            let n = 1 + rng.below(200) as u64;
            let mut dispatched_ids = Vec::new();
            let mut live = 0u64;
            for id in 0..n {
                now += rng.below(400) as u64;
                // ~1 in 8 requests names a model nobody serves
                let model = if rng.below(8) == 0 {
                    "ghost".to_string()
                } else {
                    format!("m{}", rng.below(n_lanes as u32))
                };
                let mut gr = GatewayRequest::new(id, model, vec![]);
                if rng.below(3) == 0 {
                    gr = gr.with_deadline(rng.below(1500) as u64);
                }
                gr = gr.with_priority(match rng.below(3) {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                });
                router.admit(gr, now);
                for (li, batch) in router.poll(now) {
                    live += batch.len() as u64;
                    router.note_completed(li, batch.len() as u64);
                    dispatched_ids.extend(batch.iter().map(|r| r.id));
                }
            }
            now += 10_000;
            for (li, batch) in router.flush(now) {
                live += batch.len() as u64;
                router.note_completed(li, batch.len() as u64);
                dispatched_ids.extend(batch.iter().map(|r| r.id));
            }
            // no id dispatched twice
            let mut ids = dispatched_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), dispatched_ids.len(), "a request was double-dispatched");
            // per-lane and fleet ledgers balance
            let mut total = router.unknown_model;
            for li in 0..n_lanes {
                let c = router.counts(li);
                assert_eq!(
                    c.submitted,
                    c.completed + c.rejected + c.expired,
                    "lane {li} ledger broken"
                );
                total += c.submitted;
            }
            assert_eq!(total, n, "fleet ledger broken");
            assert_eq!(live, (0..n_lanes).map(|li| router.counts(li).completed).sum::<u64>());
        });
    }

    #[test]
    fn prop_gateway_threaded_conservation() {
        // the real threaded path: random worker counts, policies and
        // deadlines never lose or double-count a frame
        crate::testkit::check(10, |rng| {
            let n = 1 + rng.below(80) as u64;
            let requests: Vec<GatewayRequest> = (0..n)
                .map(|id| {
                    let model = if id % 3 == 2 { "b" } else { "a" };
                    let mut gr =
                        GatewayRequest::new(id, model, vec![(id % 251) as u8; 16]);
                    if rng.below(4) == 0 {
                        gr = gr.with_deadline(rng.below(2000) as u64);
                    }
                    if rng.below(4) == 0 {
                        gr = gr.with_priority(Priority::Low);
                    }
                    gr
                })
                .collect();
            let lanes = vec![
                mock_lane(
                    "a",
                    1 + rng.below(3) as usize,
                    BatchPolicy {
                        max_batch: 1 + rng.below(8) as usize,
                        max_wait_us: rng.below(500) as u64,
                        queue_cap: 1 + rng.below(64) as usize,
                    },
                ),
                mock_lane(
                    "b",
                    1 + rng.below(2) as usize,
                    BatchPolicy {
                        max_batch: 1 + rng.below(4) as usize,
                        max_wait_us: rng.below(500) as u64,
                        queue_cap: 1 + rng.below(16) as usize,
                    },
                ),
            ];
            let (report, lanes) =
                serve_gateway(requests, lanes, &GatewayConfig::default()).unwrap();
            assert_eq!(report.submitted, n);
            assert!(report.conserved(), "accounting broken");
            // what the workers saw is exactly what the ledger says
            for (m, lane) in report.models.iter().zip(&lanes) {
                let seen: u64 = lane.workers.iter().map(|w| w.seen).sum();
                assert_eq!(seen, m.completed, "model {}", m.name);
            }
        });
    }

    #[test]
    fn per_model_metrics_are_populated() {
        let requests: Vec<GatewayRequest> = (0..32)
            .map(|id| GatewayRequest::new(id, if id % 2 == 0 { "a" } else { "b" }, vec![1; 8]))
            .collect();
        let lanes = vec![mock_lane("a", 2, wide_policy()), mock_lane("b", 1, wide_policy())];
        let (report, _lanes) = serve_gateway(requests, lanes, &GatewayConfig::default()).unwrap();
        assert!(report.conserved());
        for m in &report.models {
            assert_eq!(m.completed, 16, "model {}", m.name);
            assert!(m.batches > 0);
            assert!(m.mean_batch >= 1.0);
            assert!(m.latency.max_us > 0 || m.latency.p99_us > 0);
        }
        assert_eq!(report.models[0].backend, "mock");
        assert_eq!(report.models[0].workers, 2);
    }
}
