//! Inference backends behind a common trait: the overlay simulator
//! (embedded mode), the bit-packed fast engine (`nn::opt`), the
//! bit-plane popcount engine (`nn::bitplane`, the fastest CPU serving
//! hot path), and the PJRT executables (desktop mode).

use crate::compiler::lower::CompiledNet;
use crate::model::NetParams;
use crate::nn::bitplane::{BitplaneModel, Scratch as BitplaneScratch};
use crate::nn::opt::{OptModel, Scratch};
use crate::soc::Board;
use crate::Result;

/// Something that can classify batches of 32x32x3 u8 images.
pub trait Backend {
    /// One score vector per image.
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>>;

    /// Batched inference into a reusable output buffer: `out` is resized
    /// to `images.len()` and its inner vectors are reused across calls,
    /// so steady-state serving allocates nothing. The default falls back
    /// to [`Backend::infer_batch`]; the CPU engines override it.
    fn infer_batch_into(&mut self, images: &[&[u8]], out: &mut Vec<Vec<i32>>) -> Result<()> {
        let scores = self.infer_batch(images)?;
        out.clear();
        out.extend(scores);
        Ok(())
    }

    fn name(&self) -> &'static str;
    /// Largest batch the backend accepts at once.
    fn max_batch(&self) -> usize;

    /// Exact image byte length this backend accepts, when it has one.
    /// The network front-end rejects wrong-size payloads at admission so
    /// a malformed client frame can never poison a whole dispatched
    /// batch. `None` = unvalidated (mock/test backends).
    fn input_len(&self) -> Option<usize> {
        None
    }
}

/// The overlay simulator: strictly one frame at a time (the real MDP has
/// one camera and one scratchpad image slot).
pub struct OverlayBackend {
    pub board: Board,
    pub compiled: CompiledNet,
    /// Simulated cycles consumed so far (for power/throughput reports).
    pub sim_cycles: u64,
}

impl OverlayBackend {
    pub fn new(compiled: CompiledNet) -> Self {
        let board = Board::new(&compiled);
        OverlayBackend { board, compiled, sim_cycles: 0 }
    }
}

impl Backend for OverlayBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(images.len());
        for img in images {
            let (scores, report) = self.board.infer(&self.compiled, img)?;
            self.sim_cycles += report.total_cycles;
            out.push(scores);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "overlay-sim"
    }

    fn max_batch(&self) -> usize {
        1
    }

    fn input_len(&self) -> Option<usize> {
        let (h, w, c) = self.compiled.input_hwc;
        Some(h * w * c)
    }
}

/// The fast-path CPU backend: golden semantics through the `nn::opt`
/// engine (packed weights, fused requant, reusable scratch arena). No
/// cycle model — it answers as fast as the host allows, which is what
/// the serving path wants. Cheap to construct per worker thread, so
/// [`crate::coordinator::pipeline::serve_parallel`] can run one per
/// core.
pub struct OptBackend {
    pub model: OptModel,
    scratch: Scratch,
}

impl OptBackend {
    pub fn new(np: &NetParams) -> Result<Self> {
        Ok(OptBackend { model: OptModel::new(np)?, scratch: Scratch::new() })
    }
}

impl Backend for OptBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        self.model.forward_batch(images, &mut self.scratch)
    }

    fn infer_batch_into(&mut self, images: &[&[u8]], out: &mut Vec<Vec<i32>>) -> Result<()> {
        self.model.forward_batch_into(images, &mut self.scratch, out)
    }

    fn name(&self) -> &'static str {
        "nn-opt"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn input_len(&self) -> Option<usize> {
        let (h, w, c) = self.model.input_hwc;
        Some(h * w * c)
    }
}

/// The bit-plane popcount CPU backend: golden semantics through
/// `nn::bitplane` (activation bit-planes, word-wide AND+popcount,
/// shared per-window plane popcounts). Like [`OptBackend`] it is cheap
/// to construct per worker thread, and with
/// [`Backend::infer_batch_into`] a serving worker runs whole batches
/// with zero steady-state allocations.
pub struct BitplaneBackend {
    pub model: BitplaneModel,
    scratch: BitplaneScratch,
}

impl BitplaneBackend {
    pub fn new(np: &NetParams) -> Result<Self> {
        Ok(BitplaneBackend { model: BitplaneModel::new(np)?, scratch: BitplaneScratch::new() })
    }
}

impl Backend for BitplaneBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        self.model.forward_batch(images, &mut self.scratch)
    }

    fn infer_batch_into(&mut self, images: &[&[u8]], out: &mut Vec<Vec<i32>>) -> Result<()> {
        self.model.forward_batch_into(images, &mut self.scratch, out)
    }

    fn name(&self) -> &'static str {
        "nn-bitplane"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn input_len(&self) -> Option<usize> {
        let (h, w, c) = self.model.compiled.input_hwc;
        Some(h * w * c)
    }
}

/// The golden-oracle backend: straight-line `nn::layers::forward`, never
/// optimized. Slow by design — use it for validation lanes and as the
/// reference leg of differential serving tests; production lanes want
/// [`OptBackend`] or [`BitplaneBackend`].
pub struct GoldenBackend {
    pub np: NetParams,
}

impl GoldenBackend {
    pub fn new(np: &NetParams) -> Self {
        GoldenBackend { np: np.clone() }
    }
}

impl Backend for GoldenBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        images.iter().map(|img| crate::nn::layers::forward(&self.np, img)).collect()
    }

    fn name(&self) -> &'static str {
        "golden"
    }

    fn max_batch(&self) -> usize {
        16
    }

    fn input_len(&self) -> Option<usize> {
        let (h, w, c) = self.np.net.input_hwc;
        Some(h * w * c)
    }
}

/// PJRT desktop backend (wraps runtime::ModelRuntime).
pub struct PjrtBackend {
    pub rt: crate::runtime::ModelRuntime,
}

impl Backend for PjrtBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        self.rt.infer_batch(images)
    }

    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }

    fn max_batch(&self) -> usize {
        *crate::runtime::BATCHES.last().unwrap()
    }
}

/// A trivial backend for coordinator tests: returns the image checksum
/// as the score, with a configurable per-image service time in
/// microseconds (actually slept, so drain/backpressure tests can model
/// a slow engine).
pub struct MockBackend {
    pub per_image_us: u64,
    pub calls: u64,
    pub seen: u64,
}

impl MockBackend {
    pub fn new(per_image_us: u64) -> Self {
        MockBackend { per_image_us, calls: 0, seen: 0 }
    }
}

impl Backend for MockBackend {
    fn infer_batch(&mut self, images: &[&[u8]]) -> Result<Vec<Vec<i32>>> {
        self.calls += 1;
        self.seen += images.len() as u64;
        if self.per_image_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(
                self.per_image_us * images.len() as u64,
            ));
        }
        Ok(images
            .iter()
            .map(|img| vec![img.iter().map(|&b| b as i32).sum::<i32>()])
            .collect())
    }

    fn name(&self) -> &'static str {
        "mock"
    }

    fn max_batch(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lower::{compile, InputMode};
    use crate::model::weights::random_params;
    use crate::model::zoo::tiny_1cat;

    #[test]
    fn overlay_backend_counts_cycles() {
        let np = random_params(&tiny_1cat(), 8);
        let compiled = compile(&np, InputMode::Direct).unwrap();
        let mut be = OverlayBackend::new(compiled);
        let img = vec![7u8; 3072];
        let out = be.infer_batch(&[&img, &img]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], out[1]);
        assert!(be.sim_cycles > 0);
    }

    #[test]
    fn opt_backend_matches_golden() {
        let np = random_params(&tiny_1cat(), 21);
        let mut be = OptBackend::new(&np).unwrap();
        let mut rng = crate::util::Rng64::new(3);
        let imgs: Vec<Vec<u8>> = (0..3)
            .map(|_| (0..3072).map(|_| rng.next_u8()).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let out = be.infer_batch(&refs).unwrap();
        for (img, scores) in imgs.iter().zip(&out) {
            assert_eq!(scores, &crate::nn::layers::forward(&np, img).unwrap());
        }
    }

    #[test]
    fn bitplane_backend_matches_golden() {
        let np = random_params(&tiny_1cat(), 22);
        let mut be = BitplaneBackend::new(&np).unwrap();
        let mut rng = crate::util::Rng64::new(4);
        let imgs: Vec<Vec<u8>> = (0..3)
            .map(|_| (0..3072).map(|_| rng.next_u8()).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let out = be.infer_batch(&refs).unwrap();
        for (img, scores) in imgs.iter().zip(&out) {
            assert_eq!(scores, &crate::nn::layers::forward(&np, img).unwrap());
        }
    }

    #[test]
    fn infer_batch_into_reuses_buffer_and_matches_infer_batch() {
        let np = random_params(&tiny_1cat(), 23);
        let mut be = BitplaneBackend::new(&np).unwrap();
        let mut rng = crate::util::Rng64::new(5);
        let imgs: Vec<Vec<u8>> = (0..4)
            .map(|_| (0..3072).map(|_| rng.next_u8()).collect())
            .collect();
        let refs: Vec<&[u8]> = imgs.iter().map(|v| v.as_slice()).collect();
        let mut buf = Vec::new();
        be.infer_batch_into(&refs, &mut buf).unwrap();
        assert_eq!(buf, be.infer_batch(&refs).unwrap());
        // second call reuses the buffer and truncates to the batch size
        be.infer_batch_into(&refs[..2], &mut buf).unwrap();
        assert_eq!(buf.len(), 2);
        // the default (fallback) implementation agrees, via MockBackend
        let mut mock = MockBackend::new(0);
        let mut mbuf = vec![vec![99i32]; 7];
        mock.infer_batch_into(&refs, &mut mbuf).unwrap();
        assert_eq!(mbuf, mock.infer_batch(&refs).unwrap());
    }

    #[test]
    fn golden_backend_matches_forward() {
        let np = random_params(&tiny_1cat(), 24);
        let mut be = GoldenBackend::new(&np);
        let img = vec![9u8; 3072];
        let out = be.infer_batch(&[&img]).unwrap();
        assert_eq!(out[0], crate::nn::layers::forward(&np, &img).unwrap());
    }

    #[test]
    fn mock_backend_sums() {
        let mut be = MockBackend::new(10);
        let img = vec![1u8; 4];
        let out = be.infer_batch(&[&img]).unwrap();
        assert_eq!(out[0][0], 4);
        assert_eq!(be.calls, 1);
    }
}
