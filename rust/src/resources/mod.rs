//! S8: FPGA resource estimator for the overlay (paper §II: 4,895 of
//! 5,280 4-input LUTs, 4 of 8 DSP blocks, 26 of 30 4096b BRAMs, all
//! four 32 kB SPRAMs on the iCE40 UltraPlus-5K).
//!
//! Synthesis is not available here; the estimator is structural: an
//! itemized per-component budget whose line items come from the
//! published ORCA/LVE resource numbers (ORCA small RV32IM ≈ 2.1 kLUT on
//! iCE40) and sized datapath arithmetic for the custom ALUs (an 8-bit
//! add/sub cell ≈ 12 LUT4s on iCE40). The table's *structure* — what
//! consumes the chip — is the reproducible claim; the paper's total
//! anchors the calibration.

/// iCE40 UltraPlus-5K device capacity.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub luts: u32,
    pub dsp: u32,
    pub bram: u32,
    pub spram: u32,
}

/// The UP5K as on the MDP board.
pub const UP5K: Device = Device { luts: 5280, dsp: 8, bram: 30, spram: 4 };

/// Overlay configuration knobs (ablation axes for the resource table).
#[derive(Clone, Copy, Debug)]
pub struct OverlayConfig {
    /// Include the Fig. 2 binarized conv unit.
    pub cnn_accel: bool,
    /// Include LVE (vector streaming + quad-add + act-quant ALUs).
    pub lve: bool,
    /// Include the camera capture + downscale gateware.
    pub camera: bool,
    /// Parallel convolutions in the accel datapath (paper: 2).
    pub conv_parallelism: u32,
}

impl OverlayConfig {
    /// The paper's shipped configuration.
    pub fn paper() -> Self {
        OverlayConfig { cnn_accel: true, lve: true, camera: true, conv_parallelism: 2 }
    }

    /// Plain ORCA scalar core (the 73x/71x baseline).
    pub fn scalar_only() -> Self {
        OverlayConfig { cnn_accel: false, lve: false, camera: true, conv_parallelism: 0 }
    }
}

/// One line of the resource table.
#[derive(Clone, Debug)]
pub struct ResourceLine {
    pub component: &'static str,
    pub luts: u32,
    pub dsp: u32,
    pub bram: u32,
    pub spram: u32,
}

/// Full estimate.
#[derive(Clone, Debug)]
pub struct ResourceReport {
    pub lines: Vec<ResourceLine>,
    pub device: Device,
}

impl ResourceReport {
    pub fn total_luts(&self) -> u32 {
        self.lines.iter().map(|l| l.luts).sum()
    }
    pub fn total_dsp(&self) -> u32 {
        self.lines.iter().map(|l| l.dsp).sum()
    }
    pub fn total_bram(&self) -> u32 {
        self.lines.iter().map(|l| l.bram).sum()
    }
    pub fn total_spram(&self) -> u32 {
        self.lines.iter().map(|l| l.spram).sum()
    }
    pub fn fits(&self) -> bool {
        self.total_luts() <= self.device.luts
            && self.total_dsp() <= self.device.dsp
            && self.total_bram() <= self.device.bram
            && self.total_spram() <= self.device.spram
    }
}

/// Estimate the overlay's FPGA footprint.
pub fn estimate(cfg: &OverlayConfig) -> ResourceReport {
    let mut lines = Vec::new();
    // ORCA RV32IM, small config: published iCE40 numbers ≈ 2.1 kLUT,
    // 4 DSP (32x32 mul), register file + icache in BRAM.
    lines.push(ResourceLine { component: "ORCA RV32IM core", luts: 2080, dsp: 4, bram: 14, spram: 0 });
    lines.push(ResourceLine { component: "instruction memory ctrl", luts: 90, dsp: 0, bram: 6, spram: 0 });
    if cfg.lve {
        // vector sequencer, 3 address generators, VL/stride regs
        lines.push(ResourceLine { component: "LVE sequencer + AGUs", luts: 730, dsp: 0, bram: 2, spram: 0 });
        // quad 16b->32b add tree: 3 x 32b adders + control
        lines.push(ResourceLine { component: "quad-add custom ALU", luts: 120, dsp: 0, bram: 0, spram: 0 });
        // 32b->8b activation: add, round, shift, clamp
        lines.push(ResourceLine { component: "act-quant custom ALU", luts: 140, dsp: 0, bram: 0, spram: 0 });
    }
    if cfg.cnn_accel {
        // per parallel conv: 3 x (8b add/sub) window row + 16b acc chain
        // ≈ 12 LUT per 8b add/sub cell x 9 taps + window regs + mux
        let per_conv = 9 * 12 + 96 + 60;
        lines.push(ResourceLine {
            component: "binarized conv unit (Fig. 2)",
            luts: cfg.conv_parallelism * per_conv as u32 + 110,
            dsp: 0,
            bram: 1,
            spram: 0,
        });
    }
    // scratchpad uses the four 32 kB SPRAMs + banking glue
    lines.push(ResourceLine { component: "scratchpad (4x SPRAM) + banking", luts: 160, dsp: 0, bram: 0, spram: 4 });
    lines.push(ResourceLine { component: "DMA engine", luts: 330, dsp: 0, bram: 1, spram: 0 });
    lines.push(ResourceLine { component: "SPI flash controller", luts: 210, dsp: 0, bram: 0, spram: 0 });
    if cfg.camera {
        lines.push(ResourceLine { component: "camera capture + 16x downscale", luts: 390, dsp: 0, bram: 2, spram: 0 });
    }
    lines.push(ResourceLine { component: "bus arbiter / glue", luts: 115, dsp: 0, bram: 0, spram: 0 });
    ResourceReport { lines, device: UP5K }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_reported_totals() {
        let r = estimate(&OverlayConfig::paper());
        // paper: 4,895 LUTs / 4 DSP / 26 BRAM / 4 SPRAM
        let luts = r.total_luts();
        assert!((4700..=5100).contains(&luts), "LUTs = {luts}");
        assert_eq!(r.total_dsp(), 4);
        assert_eq!(r.total_bram(), 26);
        assert_eq!(r.total_spram(), 4);
        assert!(r.fits());
    }

    #[test]
    fn fits_up5k_with_headroom_shape() {
        let r = estimate(&OverlayConfig::paper());
        // paper: 4,895 of 5,280 — >88% utilization
        let util = r.total_luts() as f64 / r.device.luts as f64;
        assert!(util > 0.85 && util <= 1.0, "util = {util:.3}");
    }

    #[test]
    fn scalar_config_much_smaller() {
        let accel = estimate(&OverlayConfig::paper()).total_luts();
        let scalar = estimate(&OverlayConfig::scalar_only()).total_luts();
        assert!(scalar < accel - 1000);
    }

    #[test]
    fn conv_unit_scales_with_parallelism() {
        let mut cfg = OverlayConfig::paper();
        cfg.conv_parallelism = 4;
        let wide = estimate(&cfg).total_luts();
        let narrow = estimate(&OverlayConfig::paper()).total_luts();
        assert!(wide > narrow);
    }
}
