//! Minimal benchmarking harness (criterion is unavailable offline):
//! warms up, runs timed iterations, reports mean / stddev / min, prints
//! rows in a stable machine-grepable format, and serializes suites to
//! util_json-compatible JSON so the perf trajectory is tracked in-repo
//! (`BENCH_hotpath.json`, written by the tab_hotpath bench).

use std::collections::HashMap;
use std::time::Instant;

use crate::util_json::Json;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    /// Machine-readable JSON value for one result row. Non-finite
    /// values are clamped to 0 — `util_json` would render them as
    /// `null`, and a `null` in a numeric field breaks every downstream
    /// `as_f64()` reader of the perf-trajectory artifacts.
    pub fn to_json(&self) -> Json {
        fn num(v: f64) -> Json {
            Json::Num(if v.is_finite() { v } else { 0.0 })
        }
        let mut m = HashMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_s".to_string(), num(self.mean_s));
        m.insert("stddev_s".to_string(), num(self.stddev_s));
        m.insert("min_s".to_string(), num(self.min_s));
        Json::Obj(m)
    }
}

/// One value row (count rows, `*_us` quantile rows, QPS rows): the
/// value lives in `mean_s`/`min_s` per the BENCH conventions.
pub fn value_row(name: impl Into<String>, iters: u32, v: f64) -> BenchResult {
    let v = if v.is_finite() { v } else { 0.0 };
    BenchResult { name: name.into(), iters, mean_s: v, stddev_s: 0.0, min_s: v }
}

/// A throughput row guarded against degenerate inputs. A healthy run
/// stores seconds-per-frame (`1/per_s`); a zero-count or zero-duration
/// run (`per_s` zero or non-finite) stores `0` and appends a
/// `{name}_degenerate` marker row (value 1) so the degeneracy stays
/// visible in the artifact instead of poisoning it with NaN/inf (or a
/// silent 1e12-seconds-per-frame outlier).
pub fn push_rate_row(rows: &mut Vec<BenchResult>, name: impl Into<String>, iters: u32, per_s: f64) {
    let name = name.into();
    if per_s > 0.0 && per_s.is_finite() {
        rows.push(value_row(name, iters, 1.0 / per_s));
    } else {
        rows.push(value_row(name.clone(), iters, 0.0));
        rows.push(value_row(format!("{name}_degenerate"), 1, 1.0));
    }
}

/// Exact nearest-rank percentile over raw microsecond samples (sorts
/// in place). Unlike [`crate::coordinator::metrics::Histogram`] — a
/// log-bucketed estimator — this is exact, which is what the
/// `cluster_stage_*` rows want: they are computed from the trace ring's
/// few hundred raw samples, so there is no reason to pay bucketing
/// error. Empty input returns 0.
pub fn percentile_us(samples: &mut [u64], q: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    let q = q.clamp(0.0, 1.0);
    let idx = ((samples.len() as f64 * q).ceil() as usize).max(1) - 1;
    samples[idx.min(samples.len() - 1)]
}

/// Serialize a whole bench suite as one JSON document (schema v1:
/// `{"suite": .., "schema": 1, "results": [row, ..]}`), parseable back
/// with [`crate::util_json::parse`].
pub fn suite_json(suite: &str, results: &[BenchResult]) -> String {
    let mut m = HashMap::new();
    m.insert("suite".to_string(), Json::Str(suite.to_string()));
    m.insert("schema".to_string(), Json::Num(1.0));
    m.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    Json::Obj(m).render()
}

/// Write a bench suite to a JSON file (the perf-trajectory artifact).
pub fn write_json(
    path: impl AsRef<std::path::Path>,
    suite: &str,
    results: &[BenchResult],
) -> crate::Result<()> {
    std::fs::write(path.as_ref(), suite_json(suite, results))?;
    Ok(())
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: min,
    }
}

/// Print a result row (criterion-like).
pub fn print_result(r: &BenchResult) {
    println!(
        "bench {:40} {:>12.4} ms/iter (± {:>8.4}, min {:>10.4}, n={})",
        r.name,
        r.mean_s * 1e3,
        r.stddev_s * 1e3,
        r.min_s * 1e3,
        r.iters
    );
}

/// Run + print in one go.
pub fn run<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    print_result(&r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn degenerate_rows_store_zero_plus_a_marker_never_nan() {
        // healthy: plain seconds-per-frame, no marker
        let mut rows = Vec::new();
        push_rate_row(&mut rows, "tp", 10, 200.0);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].mean_s, 1.0 / 200.0);

        // zero-count / zero-duration inputs: 0 + marker row
        for bad in [0.0, f64::NAN, f64::INFINITY, -1.0] {
            let mut rows = Vec::new();
            push_rate_row(&mut rows, "tp", 0, bad);
            assert_eq!(rows.len(), 2, "per_s={bad}");
            assert_eq!(rows[0].name, "tp");
            assert_eq!(rows[0].mean_s, 0.0, "per_s={bad}");
            assert_eq!(rows[1].name, "tp_degenerate");
            assert_eq!(rows[1].mean_s, 1.0);
        }

        // a non-finite value reaching to_json is clamped, not nulled:
        // the artifact must stay parseable by as_f64 readers
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: f64::NAN,
            stddev_s: f64::INFINITY,
            min_s: 0.5,
        };
        let text = suite_json("s", &[r]);
        let j = crate::util_json::parse(&text).unwrap();
        let row = &j.get("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("mean_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(row.get("stddev_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(row.get("min_s").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn percentile_is_exact_nearest_rank() {
        let mut empty: Vec<u64> = Vec::new();
        assert_eq!(percentile_us(&mut empty, 0.5), 0);
        let mut one = vec![42];
        assert_eq!(percentile_us(&mut one, 0.5), 42);
        assert_eq!(percentile_us(&mut one, 0.99), 42);
        // 1..=100 shuffled: nearest-rank pXX is exactly XX
        let mut v: Vec<u64> = (1..=100).rev().collect();
        assert_eq!(percentile_us(&mut v, 0.50), 50);
        assert_eq!(percentile_us(&mut v, 0.99), 99);
        assert_eq!(percentile_us(&mut v, 1.0), 100);
        assert_eq!(percentile_us(&mut v, 0.0), 1);
    }

    #[test]
    fn suite_json_parses_back() {
        let rows = vec![
            BenchResult { name: "a".into(), iters: 3, mean_s: 0.5, stddev_s: 0.01, min_s: 0.4 },
            BenchResult { name: "b".into(), iters: 7, mean_s: 1.5e-4, stddev_s: 0.0, min_s: 1e-4 },
        ];
        let text = suite_json("hotpath", &rows);
        let j = crate::util_json::parse(&text).unwrap();
        assert_eq!(j.get("suite").unwrap().as_str(), Some("hotpath"));
        assert_eq!(j.get("schema").unwrap().as_f64(), Some(1.0));
        let rs = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(rs[1].get("mean_s").unwrap().as_f64(), Some(1.5e-4));
        assert_eq!(rs[1].get("iters").unwrap().as_f64(), Some(7.0));
    }
}
