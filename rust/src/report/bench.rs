//! Minimal benchmarking harness (criterion is unavailable offline):
//! warms up, runs timed iterations, reports mean / stddev / min, and
//! prints rows in a stable machine-grepable format.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: min,
    }
}

/// Print a result row (criterion-like).
pub fn print_result(r: &BenchResult) {
    println!(
        "bench {:40} {:>12.4} ms/iter (± {:>8.4}, min {:>10.4}, n={})",
        r.name,
        r.mean_s * 1e3,
        r.stddev_s * 1e3,
        r.min_s * 1e3,
        r.iters
    );
}

/// Run + print in one go.
pub fn run<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
    let r = bench(name, warmup, iters, f);
    print_result(&r);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert_eq!(r.iters, 5);
    }
}
