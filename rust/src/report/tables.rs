//! Table generators for E1..E10. Every function returns the formatted
//! table as a String (and is exercised by tests); `tinbinn report`
//! prints them.

use std::fmt::Write as _;
use std::path::Path;

use crate::compiler::lower::{compile, CompiledNet, InputMode};
use crate::compiler::schedule::RunReport;
use crate::data::tbd::load_tbd;
use crate::isa::baseline::{measure_rates, scalar_net_cycles};
use crate::model::weights::load_tbw;
use crate::model::zoo::{binaryconnect_orig, reduced_10cat, tiny_1cat};
use crate::model::NetParams;
use crate::nn::layers::{classify, forward};
use crate::power::PowerModel;
use crate::resources::{estimate, OverlayConfig};
use crate::soc::{cycles_to_ms, Board};
use crate::util_json;
use crate::Result;

/// Load trained weights for a task from the artifacts dir.
pub fn load_task(dir: &Path, task: &str) -> Result<NetParams> {
    load_tbw(dir.join(format!("weights_{task}.tbw")), task)
}

/// Run one overlay inference and return the report (trained weights).
pub fn overlay_run(np: &NetParams) -> Result<(CompiledNet, Vec<i32>, RunReport)> {
    let compiled = compile(np, InputMode::Direct)?;
    let mut board = Board::new(&compiled);
    let img = vec![128u8; 32 * 32 * 3];
    let (scores, report) = board.infer(&compiled, &img)?;
    Ok((compiled, scores, report))
}

// ------------------------------------------------------------------ E1

/// E1: op-count reduction (paper: reduced net has 89% fewer operations).
pub fn report_ops() -> String {
    let orig = binaryconnect_orig();
    let red = reduced_10cat();
    let tiny = tiny_1cat();
    let mut s = String::new();
    writeln!(s, "== E1: network op counts (MACs/inference) ==").unwrap();
    for n in [&orig, &red, &tiny] {
        writeln!(
            s,
            "  {:15} {:>13} MACs   {:>9.1} kB weights",
            n.name,
            n.op_count(),
            n.weight_bits() as f64 / 8.0 / 1024.0
        )
        .unwrap();
    }
    let reduction = 100.0 * (1.0 - red.op_count() as f64 / orig.op_count() as f64);
    writeln!(s, "  reduction reduced vs original: {reduction:.1}%   (paper: 89%)").unwrap();
    s
}

// ------------------------------------------------------------------ E2

/// E2: float-vs-fixed accuracy parity on the synthetic test set.
pub fn report_accuracy(dir: &Path, limit: usize) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E2: accuracy, float vs 8b fixed (paper: identical 13.6%) ==").unwrap();
    for task in ["10cat", "1cat"] {
        let np = load_task(dir, task)?;
        let ds = load_tbd(dir.join(format!("data_{task}_test.tbd")))?;
        let n = ds.len().min(limit);
        let mut fixed_ok = 0usize;
        let mut float_ok = 0usize;
        let mut agree = 0usize;
        for i in 0..n {
            let img = ds.image(i);
            let want = ds.labels[i] as usize;
            let fx = forward(&np, img)?;
            let fl = crate::nn::floatref::forward_float(&np, img)?;
            let pf = classify(&fx);
            let pl = if fl.len() == 1 {
                (fl[0] > 0.0) as usize
            } else {
                fl.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            fixed_ok += (pf == want) as usize;
            float_ok += (pl == want) as usize;
            agree += (pf == pl) as usize;
        }
        // training-side float error for reference (train_*.json)
        let train_err = std::fs::read_to_string(dir.join(format!("train_{task}.json")))
            .ok()
            .and_then(|t| util_json::parse(&t).ok())
            .and_then(|j| j.get("float_test_err").and_then(|v| v.as_f64()));
        writeln!(
            s,
            "  {task}: n={n}  float err {:.2}%  fixed err {:.2}%  |Δ| {:.2}pp  pred-agreement {:.1}%{}",
            100.0 * (1.0 - float_ok as f64 / n as f64),
            100.0 * (1.0 - fixed_ok as f64 / n as f64),
            100.0 * ((float_ok as f64 - fixed_ok as f64) / n as f64).abs(),
            100.0 * agree as f64 / n as f64,
            train_err
                .map(|e| format!("  (jax float err at export: {:.2}%)", 100.0 * e))
                .unwrap_or_default()
        )
        .unwrap();
    }
    writeln!(s, "  paper: error attributable entirely to training, not precision").unwrap();
    Ok(s)
}

// -------------------------------------------------------------- E3 / E4

/// E3/E4: overlay runtime for both classifiers.
pub fn report_timing(dir: &Path) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E3/E4: overlay runtime @24 MHz ==").unwrap();
    for (task, paper_ms) in [("10cat", 1315.0), ("1cat", 195.0)] {
        let np = load_task(dir, task)?;
        let (_c, _scores, r) = overlay_run(&np)?;
        writeln!(
            s,
            "  {task}: measured {:>7.1} ms ({} cycles, {:.2} MAC/cyc)   paper: {:>6.0} ms   ratio {:.2}x",
            r.ms(),
            r.total_cycles,
            r.macs_per_cycle(),
            paper_ms,
            paper_ms / r.ms()
        )
        .unwrap();
        for l in &r.per_layer {
            if l.cycles > 0 {
                writeln!(
                    s,
                    "      {:10} {:>9} cyc {:>7.1} ms  {:>11} MACs  dma-stall {}",
                    l.name, l.cycles, cycles_to_ms(l.cycles), l.macs, l.dma_stall_cycles
                )
                .unwrap();
            }
        }
    }
    let np10 = load_task(dir, "10cat")?;
    let np1 = load_task(dir, "1cat")?;
    let r10 = overlay_run(&np10)?.2.ms();
    let r1 = overlay_run(&np1)?.2.ms();
    writeln!(s, "  10cat/1cat runtime ratio: {:.1}x (paper: 1315/195 = 6.7x)", r10 / r1).unwrap();
    Ok(s)
}

// ------------------------------------------------------------------ E5

/// E5: accelerator speedups vs scalar ORCA (paper: conv 73x, dense 8x,
/// overall 71x).
pub fn report_speedup(dir: &Path) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E5: speedup vs scalar RV32IM (ISS-measured loops) ==").unwrap();
    let rates = measure_rates()?;
    writeln!(
        s,
        "  scalar rates: conv {:.1} cyc/MAC, dense {:.1} cyc/MAC",
        rates.conv_cycles_per_mac, rates.dense_cycles_per_mac
    )
    .unwrap();
    for task in ["10cat", "1cat"] {
        let np = load_task(dir, task)?;
        let (sc_conv, sc_dense, sc_misc) = scalar_net_cycles(&np.net, &rates);
        let (_c, _sc, r) = overlay_run(&np)?;
        let ov_conv: u64 = r.per_layer.iter().filter(|l| l.name == "conv3x3").map(|l| l.cycles).sum();
        let ov_dense: u64 = r
            .per_layer
            .iter()
            .filter(|l| l.name == "dense" || l.name == "svm")
            .map(|l| l.cycles)
            .sum();
        let conv_x = sc_conv as f64 / ov_conv.max(1) as f64;
        let dense_x = sc_dense as f64 / ov_dense.max(1) as f64;
        let overall = (sc_conv + sc_dense + sc_misc) as f64 / r.total_cycles as f64;
        writeln!(
            s,
            "  {task}: conv {:.0}x (paper 73x)   dense {:.1}x (paper 8x)   overall {:.0}x (paper 71x)",
            conv_x, dense_x, overall
        )
        .unwrap();
        writeln!(
            s,
            "      scalar total {:.1} s vs overlay {:.3} s @24 MHz",
            (sc_conv + sc_dense + sc_misc) as f64 / 24e6,
            r.total_cycles as f64 / 24e6
        )
        .unwrap();
    }
    Ok(s)
}

// ------------------------------------------------------------------ E6

/// E6: FPGA resource table.
pub fn report_resources() -> String {
    let mut s = String::new();
    writeln!(s, "== E6: iCE40 UltraPlus-5K resources ==").unwrap();
    let r = estimate(&OverlayConfig::paper());
    for l in &r.lines {
        writeln!(
            s,
            "  {:32} {:>5} LUT {:>2} DSP {:>2} BRAM {:>2} SPRAM",
            l.component, l.luts, l.dsp, l.bram, l.spram
        )
        .unwrap();
    }
    writeln!(
        s,
        "  TOTAL {:>31} LUT {:>2} DSP {:>2} BRAM {:>2} SPRAM   (paper: 4,895 / 4 / 26 / 4)",
        r.total_luts(),
        r.total_dsp(),
        r.total_bram(),
        r.total_spram()
    )
    .unwrap();
    writeln!(
        s,
        "  device {:>29} LUT {:>2} DSP {:>2} BRAM {:>2} SPRAM   fits: {}",
        r.device.luts, r.device.dsp, r.device.bram, r.device.spram, r.fits()
    )
    .unwrap();
    let scalar = estimate(&OverlayConfig::scalar_only());
    writeln!(s, "  (ablation: scalar-only ORCA = {} LUTs)", scalar.total_luts()).unwrap();
    s
}

// ------------------------------------------------------------------ E8

/// E8: power table (paper: 21.8 mW continuous 1-cat; 4.6 mW @1 fps).
pub fn report_power(dir: &Path) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E8: power model ==").unwrap();
    let m = PowerModel::default();
    for (task, paper_cont, paper_duty) in [("1cat", Some(21.8), Some(4.6)), ("10cat", None, None)] {
        let np = load_task(dir, task)?;
        let (_c, _sc, r) = overlay_run(&np)?;
        let b = m.continuous(&r);
        writeln!(
            s,
            "  {task}: continuous {:>5.1} mW{}  [static {:.2} clk {:.1} sp {:.2} mac {:.2} dma {:.2} cam {:.1}]",
            b.total_mw(),
            paper_cont.map(|p| format!(" (paper {p} mW)")).unwrap_or_default(),
            b.static_mw, b.clock_mw, b.scratchpad_mw, b.datapath_mw, b.dma_mw, b.camera_mw
        )
        .unwrap();
        let duty = m.duty_cycled(&r, 1.0);
        writeln!(
            s,
            "  {task}: duty-cycled @1 fps {:>5.1} mW{}",
            duty,
            paper_duty.map(|p| format!(" (paper {p} mW)")).unwrap_or_default()
        )
        .unwrap();
    }
    Ok(s)
}

// ------------------------------------------------------------------ E9

/// E9 (Fig. 4): per-class scores, float vs fixed, on sample images.
pub fn report_fig4(dir: &Path) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E9 (Fig. 4): person detection sample scores, float | 8b fixed ==").unwrap();
    let np = load_task(dir, "10cat")?;
    let ds = load_tbd(dir.join("data_10cat_test.tbd"))?;
    let class_names = [
        "airplane", "automobile", "bird", "cat", "person", "dog", "frog", "horse", "ship", "truck",
    ];
    // one person sample + one non-person sample
    let person = (0..ds.len()).find(|&i| ds.labels[i] == 4);
    let other = (0..ds.len()).find(|&i| ds.labels[i] != 4);
    for (tag, idx) in [("person", person), ("non-person", other)] {
        let Some(i) = idx else { continue };
        let img = ds.image(i);
        let fx = forward(&np, img)?;
        let fl = crate::nn::floatref::forward_float(&np, img)?;
        writeln!(s, "  sample: {tag} (true class: {})", class_names[ds.labels[i] as usize]).unwrap();
        for (c, name) in class_names.iter().enumerate() {
            writeln!(s, "    {:12} {:>10.1} | {:>8}", name, fl[c], fx[c]).unwrap();
        }
        let pf = classify(&fx);
        let pl = fl.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        writeln!(
            s,
            "    argmax: float={} fixed={}  agree={}",
            class_names[pl],
            class_names[pf],
            pl == pf
        )
        .unwrap();
    }
    writeln!(s, "  (more positive is better, as in the paper)").unwrap();
    Ok(s)
}

// ----------------------------------------------------------------- E10

/// E10: training ladder from the python run records.
pub fn report_train(dir: &Path) -> Result<String> {
    let mut s = String::new();
    writeln!(s, "== E10: training results (synthetic-data substitution) ==").unwrap();
    writeln!(s, "  paper ladder on CIFAR-10: 10.3% repro -> 11.8% reduced -> 13.6% no-ZCA == 13.6% fixed; 0.4% 1-cat").unwrap();
    for task in ["10cat", "1cat"] {
        let path = dir.join(format!("train_{task}.json"));
        let Ok(text) = std::fs::read_to_string(&path) else {
            writeln!(s, "  {task}: (no training record — run `make artifacts`)").unwrap();
            continue;
        };
        let j = util_json::parse(&text)?;
        let fe = j.get("float_test_err").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let xe = j.get("fixed_test_err_subset").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let ep = j.get("epochs").and_then(|v| v.as_f64()).unwrap_or(0.0);
        writeln!(
            s,
            "  {task}: float {:.2}% -> fixed {:.2}%  (Δ {:.2}pp, {} epochs)",
            100.0 * fe,
            100.0 * xe,
            100.0 * (xe - fe).abs(),
            ep as u32
        )
        .unwrap();
        if let Some(hist) = j.get("history").and_then(|v| v.as_arr()) {
            let curve: Vec<String> = hist
                .iter()
                .filter_map(|e| e.get("test_err").and_then(|v| v.as_f64()))
                .map(|e| format!("{:.1}", 100.0 * e))
                .collect();
            writeln!(s, "      err curve: [{}]%", curve.join(" -> ")).unwrap();
        }
    }
    Ok(s)
}

/// Everything except the PJRT-dependent desktop table (that one lives in
/// the CLI so `report --all` can skip it gracefully when artifacts are
/// missing).
pub fn report_all(dir: &Path, accuracy_limit: usize) -> Result<String> {
    let mut s = String::new();
    s.push_str(&report_ops());
    s.push('\n');
    s.push_str(&report_accuracy(dir, accuracy_limit)?);
    s.push('\n');
    s.push_str(&report_timing(dir)?);
    s.push('\n');
    s.push_str(&report_speedup(dir)?);
    s.push('\n');
    s.push_str(&report_resources());
    s.push('\n');
    s.push_str(&report_power(dir)?);
    s.push('\n');
    s.push_str(&report_fig4(dir)?);
    s.push('\n');
    s.push_str(&report_train(dir)?);
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> std::path::PathBuf {
        crate::runtime::artifacts_dir()
    }

    fn have_artifacts() -> bool {
        dir().join("weights_1cat.tbw").exists()
    }

    #[test]
    fn ops_table_mentions_89pct() {
        let t = report_ops();
        assert!(t.contains("88.") || t.contains("89."), "{t}");
    }

    #[test]
    fn resources_table_totals() {
        let t = report_resources();
        assert!(t.contains("TOTAL"));
        assert!(t.contains("SPRAM"));
    }

    #[test]
    fn timing_table_runs() {
        if !have_artifacts() {
            return;
        }
        let t = report_timing(&dir()).unwrap();
        assert!(t.contains("10cat"));
        assert!(t.contains("paper"));
    }

    #[test]
    fn fig4_has_person_row() {
        if !have_artifacts() {
            return;
        }
        let t = report_fig4(&dir()).unwrap();
        assert!(t.contains("person"));
        assert!(t.contains("argmax"));
    }

    #[test]
    fn accuracy_parity_small_sample() {
        if !have_artifacts() {
            return;
        }
        let t = report_accuracy(&dir(), 30).unwrap();
        assert!(t.contains("float err"));
    }
}
