//! S14: report harness — regenerates every quantitative claim of the
//! paper (experiment index E1..E11 in DESIGN.md) as printable tables,
//! each row showing paper-reported vs measured-here.

pub mod bench;
pub mod tables;

pub use tables::{
    report_accuracy, report_all, report_fig4, report_ops, report_power, report_resources,
    report_speedup, report_timing, report_train,
};
