//! TinBiNN — Tiny Binarized Neural Network Overlay, full-system reproduction.
//!
//! Layers:
//! - L3 (this crate): cycle-accurate simulator of the TinBiNN overlay
//!   (ORCA RV32IM + LVE vector engine + binarized-CNN accelerator on a
//!   Lattice iCE40 UltraPlus SoC model), overlay compiler, resource/power
//!   models, PJRT runtime for the AOT-compiled JAX model, the frame
//!   pipeline coordinator, and a native BinaryConnect trainer
//!   ([`train`]) that closes the train→TBW1→all-engines loop without
//!   the python layer.
//! - L2 (python/compile/model.py): JAX fixed-point BinaryConnect model.
//! - L1 (python/compile/kernels/*.py): Pallas binarized-conv kernels.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod data;
pub mod isa;
pub mod model;
pub mod accel;
pub mod compiler;
pub mod coordinator;
pub mod lve;
pub mod net;
pub mod nn;
pub mod obs;
pub mod power;
pub mod resources;
pub mod runtime;
pub mod soc;
pub mod train;
pub mod report;
pub mod util;
pub mod util_json;

pub mod testkit;

pub use util::TinError;
pub type Result<T> = std::result::Result<T, TinError>;
