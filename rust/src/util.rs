//! Shared small utilities: error type, deterministic PRNG.

use std::fmt;

/// Crate-wide error type.
#[derive(Debug)]
pub enum TinError {
    /// I/O failure with context.
    Io(String),
    /// Malformed artifact / file format.
    Format(String),
    /// Simulator fault (bad address, illegal instruction, ...).
    Sim(String),
    /// Configuration / API misuse.
    Config(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
}

impl fmt::Display for TinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TinError::Io(s) => write!(f, "io error: {s}"),
            TinError::Format(s) => write!(f, "format error: {s}"),
            TinError::Sim(s) => write!(f, "simulator fault: {s}"),
            TinError::Config(s) => write!(f, "config error: {s}"),
            TinError::Runtime(s) => write!(f, "runtime error: {s}"),
        }
    }
}

impl std::error::Error for TinError {}

impl From<std::io::Error> for TinError {
    fn from(e: std::io::Error) -> Self {
        TinError::Io(e.to_string())
    }
}

/// Deterministic xorshift64* PRNG — reproducible across runs and matching
/// the python-side generator used for synthetic workloads.
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// Seeded constructor; seed 0 is remapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Rng64 {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u32) -> u32 {
        if n == 0 {
            0
        } else {
            (self.next_u64() % n as u64) as u32
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform u8.
    pub fn next_u8(&mut self) -> u8 {
        (self.next_u64() >> 56) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn rng_zero_seed_ok() {
        let mut r = Rng64::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
