//! S9: activity-based power model (paper §II: the 1-category detector
//! consumes 21.8 mW running continuously; a power-optimized 1 fps duty-
//! cycled version consumes 4.6 mW).
//!
//! Board power measurements are unavailable here; the model is the
//! standard embedded-FPGA decomposition P = static + Σ(activity_i × e_i)
//! with iCE40-UltraPlus-scale coefficients. The paper publishes only the
//! two aggregate operating points, which calibrate the overall scale;
//! the *decomposition* and the duty-cycle crossover behaviour are the
//! reproducible structure (experiment E8).

use crate::compiler::schedule::RunReport;
use crate::soc::CPU_HZ;

/// Energy/power coefficients (iCE40 UP5K scale).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Static leakage + always-on rails (mW). iCE40 UP5K core leakage
    /// is ~75-100 uA at 1.2 V plus board standby.
    pub static_mw: f64,
    /// Clock tree + core switching while the CPU domain is active (mW).
    pub active_clock_mw: f64,
    /// Energy per scratchpad byte moved (nJ).
    pub nj_per_sp_byte: f64,
    /// Energy per accelerator MAC (nJ) — add/sub datapath toggle.
    pub nj_per_mac: f64,
    /// Energy per DMA byte from SPI flash (nJ) — SPI pads dominate.
    pub nj_per_dma_byte: f64,
    /// Camera + capture pipeline while sensing (mW).
    pub camera_mw: f64,
    /// Camera standby (mW) in duty-cycled sleep.
    pub camera_standby_mw: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            static_mw: 0.45,
            active_clock_mw: 9.0,
            nj_per_sp_byte: 0.012,
            nj_per_mac: 0.0045,
            nj_per_dma_byte: 0.08,
            // board-level: the paper's mW figures include the VGA sensor
            // and capture pipeline, the dominant non-FPGA consumer
            camera_mw: 8.0,
            camera_standby_mw: 0.12,
        }
    }
}

/// One computed operating point.
#[derive(Clone, Copy, Debug)]
pub struct PowerBreakdown {
    pub static_mw: f64,
    pub clock_mw: f64,
    pub scratchpad_mw: f64,
    pub datapath_mw: f64,
    pub dma_mw: f64,
    pub camera_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.static_mw + self.clock_mw + self.scratchpad_mw + self.datapath_mw + self.dma_mw + self.camera_mw
    }
}

impl PowerModel {
    /// Power while running inference back-to-back (continuous mode).
    pub fn continuous(&self, r: &RunReport) -> PowerBreakdown {
        let seconds = r.total_cycles as f64 / CPU_HZ as f64;
        let sp_bytes = (r.lve_bytes_read + r.lve_bytes_written) as f64;
        PowerBreakdown {
            static_mw: self.static_mw,
            clock_mw: self.active_clock_mw,
            scratchpad_mw: sp_bytes * self.nj_per_sp_byte * 1e-6 / seconds,
            datapath_mw: r.macs as f64 * self.nj_per_mac * 1e-6 / seconds,
            dma_mw: r.dma_bytes as f64 * self.nj_per_dma_byte * 1e-6 / seconds,
            camera_mw: self.camera_mw,
        }
    }

    /// Duty-cycled operation at `fps` frames per second: active for the
    /// inference, clock-gated sleep otherwise (the paper's
    /// "power-optimized version designed to run at one frame per second").
    pub fn duty_cycled(&self, r: &RunReport, fps: f64) -> f64 {
        let active_s = r.total_cycles as f64 / CPU_HZ as f64;
        let frac = (active_s * fps).min(1.0);
        let active = self.continuous(r).total_mw();
        let sleep = self.static_mw + self.camera_standby_mw;
        frac * active + (1.0 - frac) * sleep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lower::{compile, InputMode};
    use crate::model::weights::random_params;
    use crate::model::zoo::tiny_1cat;
    use crate::soc::Board;

    fn one_cat_report() -> RunReport {
        let np = random_params(&tiny_1cat(), 3);
        let c = compile(&np, InputMode::Direct).unwrap();
        let mut b = Board::new(&c);
        let img = vec![100u8; 3072];
        b.infer(&c, &img).unwrap().1
    }

    #[test]
    fn continuous_power_in_paper_band() {
        // paper: 21.8 mW for the continuous 1-cat detector
        let r = one_cat_report();
        let p = PowerModel::default().continuous(&r).total_mw();
        assert!((12.0..32.0).contains(&p), "continuous = {p:.1} mW");
    }

    #[test]
    fn duty_cycled_is_several_times_lower() {
        // paper: 4.6 mW at 1 fps — a ~5x reduction
        let r = one_cat_report();
        let m = PowerModel::default();
        let cont = m.continuous(&r).total_mw();
        let duty = m.duty_cycled(&r, 1.0);
        assert!(duty < cont / 2.5, "duty {duty:.1} vs cont {cont:.1}");
        assert!((1.0..8.0).contains(&duty), "duty = {duty:.2} mW");
    }

    #[test]
    fn duty_cycle_saturates_at_continuous() {
        let r = one_cat_report();
        let m = PowerModel::default();
        let cont = m.continuous(&r).total_mw();
        let sat = m.duty_cycled(&r, 1000.0);
        assert!((sat - cont).abs() < 1e-9);
    }

    #[test]
    fn breakdown_components_positive() {
        let r = one_cat_report();
        let b = PowerModel::default().continuous(&r);
        assert!(b.scratchpad_mw > 0.0);
        assert!(b.datapath_mw > 0.0);
        assert!(b.dma_mw > 0.0);
        assert!(b.total_mw() > b.static_mw);
    }
}
