//! In-tree property-testing harness (the offline environment has no
//! proptest crate; this provides the seeded-random-cases + replay core)
//! plus deterministic synthetic artifacts ([`fixtures`]).
//!
//! `check(n, f)` runs `f` against `n` independently seeded [`Rng64`]s.
//! On panic the failing seed is printed; replay a single case with
//! `TINBINN_PROP_SEED=<seed> cargo test <name>`. The CI fuzz lane
//! raises case counts across every property at once with
//! `TINBINN_PROP_CASES=<n>` (overrides the per-property default).

pub mod fixtures;

use crate::util::Rng64;

/// Marker trait for case generators (kept minimal; generation happens
/// directly from the Rng in each property).
pub trait Arbitrary {}

/// Base seed: fixed for reproducibility, overridable for replay.
fn base_seed() -> (u64, bool) {
    match std::env::var("TINBINN_PROP_SEED") {
        Ok(s) => (s.parse().expect("TINBINN_PROP_SEED must be u64"), true),
        Err(_) => (0xC0FFEE, false),
    }
}

/// Case-count override: `TINBINN_PROP_CASES=<n>` replaces every
/// property's default case count (the CI fuzz lane sets it high).
fn case_override() -> Option<u32> {
    std::env::var("TINBINN_PROP_CASES")
        .ok()
        .map(|s| s.parse().expect("TINBINN_PROP_CASES must be u32"))
}

/// Run `cases` random cases of property `f` (`TINBINN_PROP_CASES`
/// overrides `cases`; `TINBINN_PROP_SEED` replays one case).
pub fn check<F: Fn(&mut Rng64)>(cases: u32, f: F) {
    let (base, replay) = base_seed();
    if replay {
        let mut rng = Rng64::new(base);
        f(&mut rng);
        return;
    }
    let cases = case_override().unwrap_or(cases);
    for i in 0..cases {
        let seed = base ^ ((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng64::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {i}; replay with TINBINN_PROP_SEED={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        // under the CI fuzz lane (TINBINN_PROP_CASES) the override wins
        let want = case_override().unwrap_or(17);
        let count = std::cell::Cell::new(0u32);
        check(17, |_| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), want);
    }

    #[test]
    fn check_propagates_failure_with_seed() {
        let result = std::panic::catch_unwind(|| {
            check(5, |rng| {
                // fail deterministically on some case
                assert!(rng.below(2) == 0 || rng.below(1000) < 990);
            });
        });
        // may or may not fail depending on rng; just ensure no UB — smoke
        let _ = result;
    }
}
