//! Cross-layer integration tests over the trained artifacts:
//! golden model == overlay simulator == PJRT artifact, the paper's
//! numeric contract on real trained weights, and the coordinator
//! end-to-end on real dataset streams.
//!
//! All tests skip gracefully when `make artifacts` has not run.

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::coordinator::backend::OverlayBackend;
use tinbinn::coordinator::batcher::BatchPolicy;
use tinbinn::coordinator::pipeline::{run_stream, Frame, StreamConfig};
use tinbinn::data::tbd::load_tbd;
use tinbinn::model::weights::load_tbw;
use tinbinn::model::NetParams;
use tinbinn::nn::grouped::audit_net;
use tinbinn::nn::layers::{classify, forward};
use tinbinn::runtime::{artifacts_dir, ModelRuntime};
use tinbinn::soc::Board;

fn trained(task: &str) -> Option<NetParams> {
    load_tbw(artifacts_dir().join(format!("weights_{task}.tbw")), task).ok()
}

fn dataset(task: &str) -> Option<tinbinn::data::tbd::Dataset> {
    load_tbd(artifacts_dir().join(format!("data_{task}_test.tbd"))).ok()
}

#[test]
fn opt_engine_matches_golden_on_trained_weights() {
    let (Some(np), Some(ds)) = (trained("1cat"), dataset("1cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let model = tinbinn::nn::opt::OptModel::new(&np).unwrap();
    let mut scratch = tinbinn::nn::opt::Scratch::new();
    for i in 0..16 {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let fast = model.forward(img, &mut scratch).unwrap();
        assert_eq!(golden, fast, "nn::opt != golden on image {i}");
    }
}

#[test]
fn parallel_opt_serving_on_trained_weights() {
    let (Some(np), Some(ds)) = (trained("1cat"), dataset("1cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let workers: Vec<_> = (0..3)
        .map(|_| tinbinn::coordinator::backend::OptBackend::new(&np).unwrap())
        .collect();
    let frames: Vec<Frame> = (0..48)
        .map(|i| Frame { id: i as u64, image: ds.image(i % ds.len()).to_vec(), label: None })
        .collect();
    let policy = BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 128 };
    let (report, _workers) =
        tinbinn::coordinator::pipeline::serve_parallel(frames, workers, policy).unwrap();
    assert_eq!(report.completed + report.rejected, 48);
    assert!(report.completed > 0);
}

#[test]
fn golden_overlay_pjrt_agree_on_trained_weights() {
    let (Some(np), Some(ds)) = (trained("1cat"), dataset("1cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    let rt = ModelRuntime::load(artifacts_dir(), "1cat", 1).ok();
    for i in 0..8 {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let (sim, _) = board.infer(&compiled, img).unwrap();
        assert_eq!(golden, sim, "overlay != golden on image {i}");
        if let Some(rt) = &rt {
            let pjrt = rt.infer_one(img).unwrap();
            assert_eq!(golden, pjrt, "pjrt != golden on image {i}");
        }
    }
}

#[test]
fn ten_cat_overlay_matches_golden() {
    let (Some(np), Some(ds)) = (trained("10cat"), dataset("10cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    for i in 0..3 {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let (sim, _) = board.infer(&compiled, img).unwrap();
        assert_eq!(golden, sim, "10cat overlay != golden on image {i}");
    }
}

/// The paper's implicit numeric requirement: on trained nets the 16-bit
/// partial sums (per 16 input maps) never wrap, which is what makes
/// plain i32 accumulation == the hardware pipeline.
#[test]
fn trained_nets_never_overflow_i16_partials() {
    for task in ["10cat", "1cat"] {
        let (Some(np), Some(ds)) = (trained(task), dataset(task)) else {
            eprintln!("skipping: artifacts missing");
            return;
        };
        for i in 0..4 {
            let img = ds.image(i);
            let (grouped_scores, audits) = audit_net(&np, img, 16);
            for a in &audits {
                assert!(
                    !a.overflowed,
                    "{task} image {i}: i16 overflow in layer {} ({})",
                    a.layer_index, a.kind
                );
            }
            let plain = forward(&np, img).unwrap();
            assert_eq!(plain, grouped_scores, "{task}: grouped pipeline != i32 pipeline");
        }
    }
}

#[test]
fn camera_mode_agrees_with_direct_mode_predictions() {
    // The camera path loses two image rows to padding and quantizes
    // through RGB565; predictions should still agree most of the time.
    let (Some(np), Some(ds)) = (trained("1cat"), dataset("1cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let direct = compile(&np, InputMode::Direct).unwrap();
    let cam = compile(&np, InputMode::Camera).unwrap();
    let mut b_direct = Board::new(&direct);
    let mut b_cam = Board::new(&cam);
    let camera = tinbinn::soc::Camera::new(3);
    let mut agree = 0;
    let n = 12;
    for i in 0..n {
        let img = ds.image(i);
        let (sd, _) = b_direct.infer(&direct, img).unwrap();
        let frame = camera.frame_from_image(img, 32, 32);
        let rgba = camera.downscale(&frame);
        let (sc, _) = b_cam.infer(&cam, &rgba).unwrap();
        agree += (classify(&sd) == classify(&sc)) as usize;
    }
    assert!(agree * 10 >= n * 8, "camera/direct agreement too low: {agree}/{n}");
}

#[test]
fn coordinator_stream_over_overlay_backend() {
    let (Some(np), Some(ds)) = (trained("1cat"), dataset("1cat")) else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut be = OverlayBackend::new(compiled);
    let frames: Vec<Frame> = (0..20)
        .map(|i| Frame { id: i as u64, image: ds.image(i).to_vec(), label: Some(ds.labels[i]) })
        .collect();
    let cfg = StreamConfig {
        interarrival_us: 100,
        service_us_per_image: 92_500, // the overlay's simulated latency
        policy: BatchPolicy { max_batch: 1, max_wait_us: 0, queue_cap: 64 },
    };
    let r = run_stream(frames, &mut be, &cfg).unwrap();
    assert_eq!(r.completed, 20);
    assert_eq!(r.labelled, 20);
    // trained detector beats chance comfortably
    assert!(r.correct >= 14, "correct = {}", r.correct);
    assert!(be.sim_cycles > 0);
}

#[test]
fn overlay_timing_is_stable_across_inputs() {
    // data-independent runtime (no data-dependent branches in the
    // datapath) — a property the real hardware has by construction.
    let Some(np) = trained("1cat") else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    let (_, r1) = board.infer(&compiled, &vec![0u8; 3072]).unwrap();
    let (_, r2) = board.infer(&compiled, &vec![255u8; 3072]).unwrap();
    assert_eq!(r1.total_cycles, r2.total_cycles);
}

#[test]
fn weight_bytes_match_flash_image() {
    let Some(np) = trained("10cat") else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    let compiled = compile(&np, InputMode::Direct).unwrap();
    assert_eq!(compiled.flash_image.len(), np.weight_bytes());
    // paper: ~270 kB flash image (ours is the pure weight payload)
    let kb = compiled.flash_image.len() as f64 / 1024.0;
    assert!((100.0..270.0).contains(&kb), "{kb} kB");
}
