//! Cross-layer integration tests: golden model == overlay simulator ==
//! PJRT artifact, the paper's numeric contract, and the coordinator
//! end-to-end on dataset streams.
//!
//! Two tiers share every test:
//!
//! * **real** — trained artifacts from `make artifacts`, when present
//!   (accuracy thresholds apply);
//! * **synthetic** — `testkit::fixtures` otherwise: deterministic
//!   trained-like weights + self-labelled datasets, so `cargo test -q`
//!   exercises the full suite on a bare checkout instead of silently
//!   skipping.

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::coordinator::backend::OverlayBackend;
use tinbinn::coordinator::batcher::BatchPolicy;
use tinbinn::coordinator::pipeline::{run_stream, Frame, StreamConfig};
use tinbinn::data::tbd::{load_tbd, Dataset};
use tinbinn::model::weights::load_tbw;
use tinbinn::model::NetParams;
use tinbinn::nn::grouped::audit_net;
use tinbinn::nn::layers::{classify, forward};
use tinbinn::runtime::{artifacts_dir, ModelRuntime};
use tinbinn::soc::Board;
use tinbinn::testkit::fixtures;

fn trained(task: &str) -> Option<NetParams> {
    load_tbw(artifacts_dir().join(format!("weights_{task}.tbw")), task).ok()
}

fn dataset(task: &str) -> Option<Dataset> {
    load_tbd(artifacts_dir().join(format!("data_{task}_test.tbd"))).ok()
}

/// Weights + dataset for a task: the real artifacts when `make
/// artifacts` has run, the synthetic fixture tier otherwise. The bool
/// is `true` for the real tier (trained-accuracy thresholds apply).
fn task_data(task: &str) -> (NetParams, Dataset, bool) {
    match (trained(task), dataset(task)) {
        (Some(np), Some(ds)) => (np, ds, true),
        _ => {
            let (np, ds) = fixtures::synthetic_task(task).expect("synthetic fixture");
            (np.clone(), ds.clone(), false)
        }
    }
}

#[test]
fn opt_engine_matches_golden_on_task_weights() {
    let (np, ds, _) = task_data("1cat");
    let model = tinbinn::nn::opt::OptModel::new(&np).unwrap();
    let mut scratch = tinbinn::nn::opt::Scratch::new();
    for i in 0..16 {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let fast = model.forward(img, &mut scratch).unwrap();
        assert_eq!(golden, fast, "nn::opt != golden on image {i}");
    }
}

#[test]
fn parallel_opt_serving_on_task_weights() {
    let (np, ds, _) = task_data("1cat");
    let workers: Vec<_> = (0..3)
        .map(|_| tinbinn::coordinator::backend::OptBackend::new(&np).unwrap())
        .collect();
    let frames: Vec<Frame> = (0..48)
        .map(|i| Frame { id: i as u64, image: ds.image(i % ds.len()).to_vec(), label: None })
        .collect();
    let policy = BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 128 };
    let (report, _workers) =
        tinbinn::coordinator::pipeline::serve_parallel(frames, workers, policy).unwrap();
    assert_eq!(report.completed + report.rejected, 48);
    assert!(report.completed > 0);
}

#[test]
fn gateway_serves_both_tasks_bit_exact() {
    // the multi-model front door over both tasks at once, each on a
    // different engine, pinned against serial inference
    use tinbinn::coordinator::gateway::{serve_gateway, GatewayConfig, GatewayLane, GatewayRequest};
    use tinbinn::coordinator::registry::AnyBackend;
    let (np1, ds1, _) = task_data("1cat");
    let (np10, ds10, _) = task_data("10cat");
    let requests: Vec<GatewayRequest> = (0..16)
        .map(|i| {
            let (model, ds) = if i % 2 == 0 { ("1cat", &ds1) } else { ("10cat", &ds10) };
            GatewayRequest::new(i as u64, model, ds.image(i % ds.len()).to_vec())
        })
        .collect();
    let lanes = vec![
        GatewayLane {
            name: "1cat".into(),
            policy: BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 },
            workers: (0..2)
                .map(|_| {
                    AnyBackend::Bitplane(
                        tinbinn::coordinator::backend::BitplaneBackend::new(&np1).unwrap(),
                    )
                })
                .collect(),
        },
        GatewayLane {
            name: "10cat".into(),
            policy: BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 },
            workers: (0..2)
                .map(|_| {
                    AnyBackend::Opt(tinbinn::coordinator::backend::OptBackend::new(&np10).unwrap())
                })
                .collect(),
        },
    ];
    let (report, _lanes) = serve_gateway(
        requests,
        lanes,
        &GatewayConfig { collect_scores: true, ..GatewayConfig::default() },
    )
    .unwrap();
    assert!(report.conserved());
    assert_eq!(report.completed, 16);
    for m in &report.models {
        let (np, ds) = if m.name == "1cat" { (&np1, &ds1) } else { (&np10, &ds10) };
        for (id, scores) in &m.scores {
            let img = ds.image(*id as usize % ds.len());
            assert_eq!(scores, &forward(np, img).unwrap(), "model {} request {id}", m.name);
        }
    }
}

#[test]
fn gateway_hot_swaps_a_freshly_trained_model() {
    // the train->TBW1->serve loop: natively train a detector from
    // scratch, register it under a new name via the ModelRegistry, and
    // verify routing + accounting + scores stay exact alongside an
    // existing model
    use tinbinn::coordinator::gateway::{serve_gateway, GatewayConfig, GatewayLane, GatewayRequest};
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::model::zoo::{Layer, Net};
    use tinbinn::train::{fit, TrainConfig};

    let nano = Net {
        name: "nano".into(),
        input_hwc: (8, 8, 3),
        layers: vec![
            Layer::Conv3x3 { cout: 8 },
            Layer::MaxPool2,
            Layer::Dense { nout: 16 },
            Layer::Svm { nout: 1 },
        ],
    };
    let (np_fixture, ds) = fixtures::eval_set(&nano, 16).unwrap();
    // a short budget: this test pins the swap mechanics, not accuracy
    let cfg = TrainConfig { epochs: 8, stop_acc: 0.9, ..TrainConfig::default() };
    let trained = fit(&nano, &ds, &cfg).unwrap();
    assert_ne!(
        trained.params.params, np_fixture.params,
        "training must produce new parameters"
    );

    let mut reg = ModelRegistry::new();
    reg.register(
        ModelSpec { name: "stock".into(), backend: BackendKind::Opt, workers: 1 },
        np_fixture.clone(),
    )
    .unwrap();
    // register stale (fixture) params under the new name, then hot-swap
    // in the freshly trained ones — the bit-exactness assertions below
    // only pass if replace() actually stored the new params
    reg.register(
        ModelSpec { name: "fresh".into(), backend: BackendKind::Bitplane, workers: 2 },
        np_fixture.clone(),
    )
    .unwrap();
    reg.replace("fresh", trained.params.clone()).unwrap();

    let policy = BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 };
    let mut lanes = Vec::new();
    for entry in reg.entries() {
        lanes.push(GatewayLane {
            name: entry.spec.name.clone(),
            policy,
            workers: reg.build_pool(entry).unwrap(),
        });
    }
    // mixed traffic: both models plus an unknown name
    let requests: Vec<GatewayRequest> = (0..24)
        .map(|i| {
            let model = match i % 3 {
                0 => "stock",
                1 => "fresh",
                _ => "ghost",
            };
            GatewayRequest::new(i as u64, model, ds.image(i % ds.len()).to_vec())
        })
        .collect();
    let (report, _lanes) = serve_gateway(
        requests,
        lanes,
        &GatewayConfig { collect_scores: true, ..GatewayConfig::default() },
    )
    .unwrap();
    assert!(report.conserved(), "submitted != completed + rejected + expired");
    assert_eq!(report.submitted, 24);
    assert_eq!(report.unknown_model, 8);
    assert_eq!(report.completed, 16);
    for m in &report.models {
        let np = if m.name == "stock" { &np_fixture } else { &trained.params };
        assert_eq!(m.completed, 8, "model {}", m.name);
        for (id, scores) in &m.scores {
            let img = ds.image(*id as usize % ds.len());
            assert_eq!(
                scores,
                &forward(np, img).unwrap(),
                "model {} request {id} diverged from serial inference",
                m.name
            );
        }
    }
}

#[test]
fn golden_overlay_pjrt_agree_on_task_weights() {
    let (np, ds, real) = task_data("1cat");
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    // PJRT artifacts only exist on the real tier (and only when a real
    // PJRT is linked)
    let rt = if real { ModelRuntime::load(artifacts_dir(), "1cat", 1).ok() } else { None };
    for i in 0..8 {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let (sim, _) = board.infer(&compiled, img).unwrap();
        assert_eq!(golden, sim, "overlay != golden on image {i}");
        if let Some(rt) = &rt {
            let pjrt = rt.infer_one(img).unwrap();
            assert_eq!(golden, pjrt, "pjrt != golden on image {i}");
        }
    }
}

#[test]
fn ten_cat_overlay_matches_golden() {
    let (np, ds, real) = task_data("10cat");
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    // the 10-cat sim is the slowest path in the suite; two images pin
    // the synthetic tier, trained runs keep the original three
    let n = if real { 3 } else { 2 };
    for i in 0..n {
        let img = ds.image(i);
        let golden = forward(&np, img).unwrap();
        let (sim, _) = board.infer(&compiled, img).unwrap();
        assert_eq!(golden, sim, "10cat overlay != golden on image {i}");
    }
}

/// The paper's implicit numeric requirement: the 16-bit partial sums
/// (per 16 input maps) never wrap, which is what makes plain i32
/// accumulation == the hardware pipeline. The synthetic fixtures are
/// generated to honor the same contract.
#[test]
fn task_nets_never_overflow_i16_partials() {
    for task in ["10cat", "1cat"] {
        let (np, ds, real) = task_data(task);
        let n = if real { 4 } else { 2 };
        for i in 0..n {
            let img = ds.image(i);
            let (grouped_scores, audits) = audit_net(&np, img, 16);
            for a in &audits {
                assert!(
                    !a.overflowed,
                    "{task} image {i}: i16 overflow in layer {} ({})",
                    a.layer_index, a.kind
                );
            }
            let plain = forward(&np, img).unwrap();
            assert_eq!(plain, grouped_scores, "{task}: grouped pipeline != i32 pipeline");
        }
    }
}

/// The 32x32x3 image the camera-mode schedule effectively feeds the
/// CNN: 40x30 RGBA rows land on image rows 1..31 (rows 0 and 31 fall
/// into the black padding), columns crop 4..36.
fn camera_effective_input(rgba: &[u8]) -> Vec<u8> {
    let mut img = vec![0u8; 32 * 32 * 3];
    for y in 1..31usize {
        for x in 0..32usize {
            for ch in 0..3usize {
                img[(y * 32 + x) * 3 + ch] = rgba[((y - 1) * 40 + x + 4) * 4 + ch];
            }
        }
    }
    img
}

#[test]
fn camera_mode_agrees_with_direct_mode() {
    let (np, ds, real) = task_data("1cat");
    let direct = compile(&np, InputMode::Direct).unwrap();
    let cam = compile(&np, InputMode::Camera).unwrap();
    let mut b_direct = Board::new(&direct);
    let mut b_cam = Board::new(&cam);
    let camera = tinbinn::soc::Camera::new(3);
    let mut agree = 0;
    let n = if real { 12 } else { 6 };
    for i in 0..n {
        let img = ds.image(i);
        let (sd, _) = b_direct.infer(&direct, img).unwrap();
        let frame = camera.frame_from_image(img, 32, 32);
        let rgba = camera.downscale(&frame);
        let (sc, _) = b_cam.infer(&cam, &rgba).unwrap();
        agree += (classify(&sd) == classify(&sc)) as usize;
        // every tier: the camera-mode overlay must be bit-exact with the
        // golden model on the effective (cropped, quantized) input —
        // pins the de-interleave/crop schedule itself
        let golden_cam = forward(&np, &camera_effective_input(&rgba)).unwrap();
        assert_eq!(sc, golden_cam, "camera-mode overlay != golden on effective input {i}");
    }
    if real {
        // trained nets are robust to the camera's quantization loss;
        // random-weight fixtures are deliberately input-sensitive, so
        // prediction agreement is only a trained-tier claim
        assert!(agree * 10 >= n * 8, "camera/direct agreement too low: {agree}/{n}");
    }
}

#[test]
fn coordinator_stream_over_overlay_backend() {
    let (np, ds, _) = task_data("1cat");
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut be = OverlayBackend::new(compiled);
    let frames: Vec<Frame> = (0..20)
        .map(|i| Frame { id: i as u64, image: ds.image(i).to_vec(), label: Some(ds.labels[i]) })
        .collect();
    let cfg = StreamConfig {
        interarrival_us: 100,
        service_us_per_image: 92_500, // the overlay's simulated latency
        policy: BatchPolicy { max_batch: 1, max_wait_us: 0, queue_cap: 64 },
    };
    let r = run_stream(frames, &mut be, &cfg).unwrap();
    assert_eq!(r.completed, 20);
    assert_eq!(r.labelled, 20);
    // trained detector beats chance comfortably; fixture labels are the
    // model's own predictions, so the bound holds on both tiers
    assert!(r.correct >= 14, "correct = {}", r.correct);
    assert!(be.sim_cycles > 0);
}

#[test]
fn overlay_timing_is_stable_across_inputs() {
    // data-independent runtime (no data-dependent branches in the
    // datapath) — a property the real hardware has by construction.
    let (np, _, _) = task_data("1cat");
    let compiled = compile(&np, InputMode::Direct).unwrap();
    let mut board = Board::new(&compiled);
    let (_, r1) = board.infer(&compiled, &vec![0u8; 3072]).unwrap();
    let (_, r2) = board.infer(&compiled, &vec![255u8; 3072]).unwrap();
    assert_eq!(r1.total_cycles, r2.total_cycles);
}

#[test]
fn weight_bytes_match_flash_image() {
    let (np, _, _) = task_data("10cat");
    let compiled = compile(&np, InputMode::Direct).unwrap();
    assert_eq!(compiled.flash_image.len(), np.weight_bytes());
    // paper: ~270 kB flash image (ours is the pure weight payload); the
    // synthetic fixture shares the zoo geometry, so the bound holds
    let kb = compiled.flash_image.len() as f64 / 1024.0;
    assert!((100.0..270.0).contains(&kb), "{kb} kB");
}

#[test]
fn net_loopback_scores_bit_exact_on_every_backend() {
    // the PR-5 acceptance criterion: scores served over TCP (TBNP/1)
    // are identical to direct Backend::infer for the same images on
    // every registered engine — golden, opt, bitplane, and the
    // cycle-accurate overlay — all behind one listening socket
    use tinbinn::coordinator::backend::Backend;
    use tinbinn::coordinator::gateway::GatewayLane;
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::net::{Client, MonotonicClock, NetServer, ServerConfig, Status};

    let (np1, ds1, _) = task_data("1cat");
    let (np10, ds10, _) = task_data("10cat");
    let mut reg = ModelRegistry::new();
    for (name, backend, np) in [
        ("golden1", BackendKind::Golden, &np1),
        ("opt10", BackendKind::Opt, &np10),
        ("bitplane1", BackendKind::Bitplane, &np1),
        ("overlay1", BackendKind::Overlay, &np1),
    ] {
        reg.register(ModelSpec { name: name.into(), backend, workers: 1 }, np.clone())
            .unwrap();
    }
    let mut lanes = Vec::new();
    for entry in reg.entries() {
        lanes.push(GatewayLane {
            name: entry.spec.name.clone(),
            policy: BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 },
            workers: reg.build_pool(entry).unwrap(),
        });
    }
    let srv = NetServer::start(
        "127.0.0.1:0",
        lanes,
        ServerConfig::default(),
        std::sync::Arc::new(MonotonicClock::new()),
    )
    .unwrap();
    let mut client = Client::connect(srv.local_addr()).unwrap();

    let n = 4usize;
    let mut checked = 0usize;
    for entry in reg.entries() {
        let (np, ds) = if entry.spec.name == "opt10" { (&np10, &ds10) } else { (&np1, &ds1) };
        let imgs: Vec<&[u8]> = (0..n).map(|i| ds.image(i)).collect();
        // the direct leg: the same registry entry, Backend::infer_batch
        let mut direct = reg.build_pool(entry).unwrap();
        let want = direct[0].infer_batch(&imgs).unwrap();
        let resps = client.infer_pipelined(&entry.spec.name, &imgs).unwrap();
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.status, Status::Ok, "{} image {i}", entry.spec.name);
            assert_eq!(
                r.scores, want[i],
                "wire scores diverged from direct Backend::infer ({} image {i})",
                entry.spec.name
            );
            assert_eq!(
                r.scores,
                forward(np, imgs[i]).unwrap(),
                "wire scores diverged from the golden oracle ({} image {i})",
                entry.spec.name
            );
            assert!(r.completed_us >= r.admitted_us);
            checked += 1;
        }
    }
    assert_eq!(checked, 4 * n, "every backend verified over the wire");

    let report = srv.shutdown().unwrap();
    assert!(report.conserved(), "loopback serving broke the ledger");
    assert_eq!(report.completed, (4 * n) as u64);
    for m in &report.models {
        assert_eq!(m.completed, n as u64, "model {}", m.name);
        assert!(m.latency.p99_us > 0, "per-model quantiles populated ({})", m.name);
    }
}

#[test]
fn net_load_generator_over_two_real_models_conserves_and_reports_quantiles() {
    // bench-load's library path against two engines at once: no request
    // lost, client and server ledgers both balance, and the
    // BENCH_serve.json row set carries p50/p99 for both models
    use tinbinn::coordinator::gateway::GatewayLane;
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::net::{parse_mix, run_load, LoadConfig, LoadMode, MonotonicClock, NetServer, ServerConfig};

    let (np1, ds1, _) = task_data("1cat");
    let (np10, ds10, _) = task_data("10cat");
    let mut reg = ModelRegistry::new();
    reg.register(
        ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 2 },
        np1,
    )
    .unwrap();
    reg.register(ModelSpec { name: "10cat".into(), backend: BackendKind::Opt, workers: 2 }, np10)
        .unwrap();
    let mut lanes = Vec::new();
    for entry in reg.entries() {
        lanes.push(GatewayLane {
            name: entry.spec.name.clone(),
            policy: BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 4096 },
            workers: reg.build_pool(entry).unwrap(),
        });
    }
    let srv = NetServer::start(
        "127.0.0.1:0",
        lanes,
        ServerConfig::default(),
        std::sync::Arc::new(MonotonicClock::new()),
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let mut images = std::collections::HashMap::new();
    images.insert("1cat".to_string(), (0..8).map(|i| ds1.image(i).to_vec()).collect::<Vec<_>>());
    images.insert("10cat".to_string(), (0..8).map(|i| ds10.image(i).to_vec()).collect::<Vec<_>>());
    let cfg = LoadConfig {
        conns: 2,
        requests: 16,
        mix: parse_mix("1cat:bitplane=0.5,10cat:opt=0.5").unwrap(),
        mode: LoadMode::Closed { inflight: 4 },
        deadline_us: None,
        low_frac: 0.0,
        seed: 3,
        reconnect: None,
        trace_sample: 0,
    };
    let load = run_load(&addr, &cfg, &images).unwrap();
    assert_eq!(load.sent, 16);
    assert_eq!(load.lost, 0, "every request answered");
    assert!(load.conserved());
    assert_eq!(load.ok, 16, "an unloaded server completes everything");

    let rows = load.bench_rows();
    for want in [
        "net_load_fleet",
        "net_load_1cat",
        "net_load_10cat",
        "gateway_1cat_p50_us",
        "gateway_1cat_p99_us",
        "gateway_10cat_p50_us",
        "gateway_10cat_p99_us",
        "net_load_unanswered",
    ] {
        assert!(rows.iter().any(|r| r.name == want), "missing bench row {want}");
    }

    let report = srv.shutdown().unwrap();
    assert!(report.conserved(), "server ledger broken under generated load");
    assert_eq!(report.completed, 16);
    assert_eq!(report.models.len(), 2);
}

#[test]
fn cluster_router_over_real_replicas_is_bit_exact_and_survives_a_kill() {
    // the PR-7 acceptance criterion end to end: two real-engine replica
    // servers behind the cluster router — routed scores identical to
    // the golden oracle, then one replica dies mid-load and every
    // ledger (client, router, both replicas) still balances with zero
    // requests lost
    use tinbinn::coordinator::gateway::GatewayLane;
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::net::{
        parse_mix, run_cluster_load, Client, ClusterConfig, ClusterRouter, ClusterScenario,
        LoadConfig, LoadMode, MonotonicClock, NetServer, ServerConfig, Status,
    };
    use std::time::Duration;

    let (np1, ds1, _) = task_data("1cat");
    let start_replica = || {
        let mut reg = ModelRegistry::new();
        reg.register(
            ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 2 },
            np1.clone(),
        )
        .unwrap();
        let mut lanes = Vec::new();
        for entry in reg.entries() {
            lanes.push(GatewayLane {
                name: entry.spec.name.clone(),
                policy: BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 4096 },
                workers: reg.build_pool(entry).unwrap(),
            });
        }
        NetServer::start(
            "127.0.0.1:0",
            lanes,
            ServerConfig::default(),
            std::sync::Arc::new(MonotonicClock::new()),
        )
        .unwrap()
    };
    let victim = start_replica();
    let survivor = start_replica();

    let mut ccfg = ClusterConfig::new(vec![victim.local_addr(), survivor.local_addr()]);
    ccfg.retry.base_backoff_us = 1_000;
    ccfg.probe.interval_us = 20_000;
    ccfg.probe.fail_threshold = 2;
    let router =
        ClusterRouter::start("127.0.0.1:0", ccfg, std::sync::Arc::new(MonotonicClock::new()))
            .unwrap();
    let addr = router.local_addr().to_string();

    // leg 1: routed scores are bit-exact against the golden oracle
    let mut cl = Client::connect(router.local_addr()).unwrap();
    for i in 0..4usize {
        let img = ds1.image(i);
        let r = cl.infer("1cat", img).unwrap();
        assert_eq!(r.status, Status::Ok, "routed image {i}");
        assert_eq!(r.scores, forward(&np1, img).unwrap(), "routed scores diverged (image {i})");
    }
    drop(cl);

    // leg 2: a replica dies mid-load; the router must absorb the death
    let mut images = std::collections::HashMap::new();
    images.insert("1cat".to_string(), (0..8).map(|i| ds1.image(i).to_vec()).collect::<Vec<_>>());
    let lcfg = LoadConfig {
        conns: 2,
        requests: 60,
        mix: parse_mix("1cat=1").unwrap(),
        mode: LoadMode::Closed { inflight: 2 },
        deadline_us: None,
        low_frac: 0.0,
        seed: 9,
        reconnect: None,
        trace_sample: 0,
    };
    let scenario = ClusterScenario {
        victim: Some(victim.local_addr().to_string()),
        kill_after: Duration::from_millis(20),
    };
    let load = run_cluster_load(&addr, &lcfg, &images, &scenario).unwrap();
    assert!(load.conserved(), "client ledger broken through the router");
    assert_eq!(load.lost, 0, "the router must absorb the replica death (lost {})", load.lost);
    assert_eq!(load.answered(), 60, "every request answered exactly once");

    let rep = router.shutdown().unwrap();
    assert!(rep.conserved(), "{}", rep.summary_line());
    assert_eq!(rep.received, 64, "4 direct infers + 60 load requests");
    let vrep = victim.wait().unwrap();
    assert!(vrep.conserved(), "victim ledger broken by the mid-run kill");
    let srep = survivor.shutdown().unwrap();
    assert!(srep.conserved(), "survivor ledger broken under failover load");
}

#[test]
fn stitched_cluster_traces_obey_the_span_sum_inequality() {
    // the tracing acceptance criterion end to end: real-engine replicas
    // behind the router, every request sampled, and each stitched
    // timeline must satisfy `front + forward + replica_e2e ≤ router
    // total ≤ client-observed e2e`, with the sampled-trace count
    // reconciling against the router's own ledger. One connection,
    // strictly sequential sends: ids are unique and the client clock
    // brackets each request end to end.
    use tinbinn::coordinator::batcher::Priority;
    use tinbinn::coordinator::gateway::GatewayLane;
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::net::{
        Client, ClusterConfig, ClusterRouter, MonotonicClock, NetServer, ServerConfig, Status,
    };
    use tinbinn::obs::Snapshot;

    let (np1, ds1, _) = task_data("1cat");
    let start_replica = || {
        let mut reg = ModelRegistry::new();
        reg.register(
            ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 2 },
            np1.clone(),
        )
        .unwrap();
        let mut lanes = Vec::new();
        for entry in reg.entries() {
            lanes.push(GatewayLane {
                name: entry.spec.name.clone(),
                policy: BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 },
                workers: reg.build_pool(entry).unwrap(),
            });
        }
        NetServer::start(
            "127.0.0.1:0",
            lanes,
            ServerConfig::default(),
            std::sync::Arc::new(MonotonicClock::new()),
        )
        .unwrap()
    };
    let ra = start_replica();
    let rb = start_replica();
    let router = ClusterRouter::start(
        "127.0.0.1:0",
        ClusterConfig::new(vec![ra.local_addr(), rb.local_addr()]),
        std::sync::Arc::new(MonotonicClock::new()),
    )
    .unwrap();

    let mut cl = Client::connect(router.local_addr()).unwrap();
    let n = 12usize;
    let mut client_e2e = std::collections::HashMap::new();
    for i in 0..n {
        let img = ds1.image(i % ds1.len()).to_vec();
        let t0 = std::time::Instant::now();
        let id = cl.send_with("1cat", img, Priority::Normal, None, true).unwrap();
        cl.flush().unwrap();
        let resp = cl.recv().unwrap();
        let e2e = t0.elapsed().as_micros() as u64;
        assert_eq!(resp.id, id);
        assert_eq!(resp.status, Status::Ok, "request {i}");
        let wire = resp.trace.unwrap_or_else(|| {
            panic!("sampled request {i} answered without a trace block")
        });
        assert!(wire.e2e_us() <= e2e, "replica e2e exceeds the client clock (request {i})");
        client_e2e.insert(id, e2e);
    }
    // the ring travels in the same TBNS frame the stats command reads
    let snap = Snapshot::parse(&cl.stats().unwrap()).unwrap();
    drop(cl);

    assert_eq!(snap.counter("cluster.received"), Some(n as u64));
    assert_eq!(
        snap.counter("cluster.traced"),
        Some(n as u64),
        "at 1-in-1 sampling every received request must stitch a trace"
    );
    assert_eq!(snap.traces.len(), n, "all {n} traces fit in the ring");
    let mut seen_ids: Vec<u64> = snap.traces.iter().map(|t| t.id).collect();
    seen_ids.sort_unstable();
    assert_eq!(seen_ids, (0..n as u64).collect::<Vec<_>>(), "one trace per request id");
    for t in &snap.traces {
        assert!(t.replica.is_some(), "trace {} missing the replica block", t.id);
        assert!(
            t.attempts.last().map_or(false, |a| a.ok && a.start_us <= a.sent_us && a.sent_us <= a.end_us),
            "trace {} has no ordered successful attempt",
            t.id
        );
        // all stamps are microsecond truncations of monotonic clocks in
        // three domains (client, router, replica), so physical
        // containment shows up with up to a few µs of rounding slack
        let sum = t.front_us() + t.forward_us() + t.replica_e2e_us();
        assert!(
            sum <= t.total_us() + 5,
            "trace {}: front {} + forward {} + replica {} exceeds total {}",
            t.id,
            t.front_us(),
            t.forward_us(),
            t.replica_e2e_us(),
            t.total_us()
        );
        let e2e = client_e2e[&t.id];
        assert!(
            t.total_us() <= e2e + 5,
            "trace {}: router total {}us exceeds the client-observed {}us",
            t.id,
            t.total_us(),
            e2e
        );
    }

    // the exported Chrome trace is valid JSON with one request span per
    // trace (the CI lane re-checks nesting with a real JSON parser)
    let chrome = tinbinn::obs::chrome_trace_json(&snap.traces);
    let doc = tinbinn::util_json::parse(&chrome).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() >= n, "at least one span per stitched trace");

    let rep = router.shutdown().unwrap();
    assert!(rep.conserved(), "{}", rep.summary_line());
    assert_eq!(rep.received, n as u64);
    assert_eq!(rep.traced, n as u64, "ledger and ring disagree on sampled traces");
    let a_rep = ra.shutdown().unwrap();
    let b_rep = rb.shutdown().unwrap();
    assert!(a_rep.conserved() && b_rep.conserved(), "replica ledgers broken under tracing");
    assert_eq!(
        a_rep.completed + b_rep.completed,
        n as u64,
        "the replicas served exactly the sampled requests"
    );
}

#[test]
fn stats_frame_agrees_exactly_with_the_drain_ledger() {
    // the PR-9 acceptance criterion: a live TBNS/1 snapshot fetched
    // over the wire reads the same atomics the drain report settles
    // from. After traffic quiesces (every response read back by the
    // client) a snapshot and the subsequent drain report must agree
    // EXACTLY — per-model ledgers, the wire response ledger — and the
    // per-stage histograms must have counted every request, with each
    // slow-ring trace's stage split fitting inside its end-to-end time.
    use tinbinn::coordinator::gateway::GatewayLane;
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::net::{Client, MonotonicClock, NetServer, ServerConfig, Status};
    use tinbinn::obs::Snapshot;

    let (np1, ds1, _) = task_data("1cat");
    let (np10, ds10, _) = task_data("10cat");
    let mut reg = ModelRegistry::new();
    reg.register(
        ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 2 },
        np1,
    )
    .unwrap();
    reg.register(ModelSpec { name: "10cat".into(), backend: BackendKind::Opt, workers: 1 }, np10)
        .unwrap();
    let mut lanes = Vec::new();
    for entry in reg.entries() {
        lanes.push(GatewayLane {
            name: entry.spec.name.clone(),
            policy: BatchPolicy { max_batch: 4, max_wait_us: 100, queue_cap: 1024 },
            workers: reg.build_pool(entry).unwrap(),
        });
    }
    let srv = NetServer::start(
        "127.0.0.1:0",
        lanes,
        ServerConfig::default(),
        std::sync::Arc::new(MonotonicClock::new()),
    )
    .unwrap();
    let mut client = Client::connect(srv.local_addr()).unwrap();

    // a pre-traffic snapshot parses and shows zeroed, pre-registered
    // wire series — and proves the Stats frame itself stays off the
    // request ledger
    let early = Snapshot::parse(&client.stats().unwrap()).unwrap();
    assert_eq!(early.counter("wire.settled"), Some(0), "stats frames must not settle responses");
    // rendered before its own fetch is counted, so the first reads 0
    assert_eq!(early.counter("obs.stats_served"), Some(0));

    let n = 6usize;
    let imgs1: Vec<&[u8]> = (0..n).map(|i| ds1.image(i)).collect();
    let imgs10: Vec<&[u8]> = (0..n).map(|i| ds10.image(i)).collect();
    for r in client.infer_pipelined("1cat", &imgs1).unwrap() {
        assert_eq!(r.status, Status::Ok);
    }
    for r in client.infer_pipelined("10cat", &imgs10).unwrap() {
        assert_eq!(r.status, Status::Ok);
    }

    // every response has been read back, so the shard that owns this
    // connection already flushed (and stage-stamped) all of them before
    // it can see the Stats frame: this snapshot is final
    let snap = Snapshot::parse(&client.stats().unwrap()).unwrap();
    drop(client);
    let report = srv.shutdown().unwrap();
    assert!(report.conserved(), "drain ledger broken");

    // exact agreement, per model and on the wire ledger — the snapshot
    // and the report read the same atomics, so any drift is a bug
    assert_eq!(report.models.len(), 2);
    for m in &report.models {
        for (field, want) in [
            ("submitted", m.submitted),
            ("completed", m.completed),
            ("rejected", m.rejected),
            ("expired", m.expired),
        ] {
            assert_eq!(
                snap.counter(&format!("model.{}.{field}", m.name)),
                Some(want),
                "stats frame disagrees with the drain ledger on model.{}.{field}",
                m.name
            );
        }
        assert_eq!(m.completed, n as u64, "model {}", m.name);
    }
    assert_eq!(snap.counter("wire.settled"), Some(report.settled_responses));
    assert_eq!(snap.counter("wire.answered"), Some(report.answered_responses));
    assert_eq!(snap.counter("wire.dropped"), Some(report.dropped_responses));
    assert_eq!(snap.counter("gateway.unknown_model"), Some(report.unknown_model));
    assert_eq!(snap.counter("obs.stats_served"), Some(1), "the earlier fetch was counted");

    // per-stage histograms exist per served model and saw every request
    let mut models = snap.model_names();
    models.sort();
    assert_eq!(models, vec!["10cat".to_string(), "1cat".to_string()]);
    for model in ["1cat", "10cat"] {
        for series in ["e2e", "stage_queue", "stage_infer", "stage_outbox"] {
            let h = snap
                .hist(&format!("{series}.{model}"))
                .unwrap_or_else(|| panic!("missing histogram {series}.{model}"));
            assert_eq!(h.count, n as u64, "{series}.{model} counted every request");
        }
    }

    // the slow ring captured stage traces, and no trace's stage split
    // exceeds its end-to-end time
    assert!(!report.slow_traces.is_empty(), "slow ring empty after {n} requests per model");
    for t in &report.slow_traces {
        assert!(
            t.queue_us() + t.infer_us() + t.outbox_us() <= t.e2e_us(),
            "stage split exceeds e2e: {}",
            t.summary_line()
        );
    }
}
