//! Quickstart: load trained weights, classify one image five ways —
//! golden model, the nn::opt fast engine, the nn::bitplane popcount
//! engine, the cycle-accurate overlay simulator, and the AOT-compiled
//! XLA artifact via PJRT — and show they agree bit-exactly.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::data::tbd::load_tbd;
use tinbinn::model::weights::load_tbw;
use tinbinn::nn::bitplane::BitplaneModel;
use tinbinn::nn::layers::{classify, forward};
use tinbinn::nn::opt::{OptModel, Scratch};
use tinbinn::runtime::{artifacts_dir, ModelRuntime};
use tinbinn::soc::Board;

fn main() -> tinbinn::Result<()> {
    let dir = artifacts_dir();
    let task = "1cat";
    let np = load_tbw(dir.join("weights_1cat.tbw"), task)?;
    let ds = load_tbd(dir.join("data_1cat_test.tbd"))?;
    let img = ds.image(0);
    println!("TinBiNN quickstart — {} ({} MACs)", np.net.name, np.net.op_count());

    // 1. golden fixed-point model
    let golden = forward(&np, img)?;
    println!("golden scores:  {golden:?}  -> class {}", classify(&golden));

    // 1b. the fast path: packed weights, fused requant, zero per-layer
    // allocations — the engine the serving coordinator runs on
    let engine = OptModel::new(&np)?;
    let mut scratch = Scratch::new();
    let fast = engine.forward(img, &mut scratch)?;
    println!("opt scores:     {fast:?}  -> class {}", classify(&fast));
    assert_eq!(golden, fast, "nn::opt must be bit-exact");

    // 1c. the popcount datapath: activations transposed into 8 packed
    // bit-planes, every channel an AND+popcount walk — the fastest
    // single-image CPU engine and the serving default
    let popcnt_engine = BitplaneModel::new(&np)?;
    let mut popcnt_scratch = tinbinn::nn::bitplane::Scratch::new();
    let popcnt = popcnt_engine.forward(img, &mut popcnt_scratch)?;
    println!("bitplane scores: {popcnt:?}  -> class {}", classify(&popcnt));
    assert_eq!(golden, popcnt, "nn::bitplane must be bit-exact");

    // 2. cycle-accurate overlay simulation
    let compiled = compile(&np, InputMode::Direct)?;
    let mut board = Board::new(&compiled);
    let (sim, report) = board.infer(&compiled, img)?;
    println!(
        "overlay scores: {sim:?}  -> class {}   ({:.1} ms simulated @24 MHz)",
        classify(&sim),
        report.ms()
    );
    assert_eq!(golden, sim, "overlay must be bit-exact");

    // 3. AOT XLA artifact on PJRT (the python-compiled model, no python)
    match ModelRuntime::load(&dir, task, 1) {
        Ok(rt) => {
            let pjrt = rt.infer_one(img)?;
            println!("pjrt scores:    {pjrt:?}  -> class {}", classify(&pjrt));
            assert_eq!(golden, pjrt, "PJRT artifact must be bit-exact");
        }
        Err(e) => println!("(pjrt skipped: {e})"),
    }

    println!("label: {}  — all paths agree", ds.labels[0]);
    Ok(())
}
