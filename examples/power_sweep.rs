//! E8 — power operating-point sweep: continuous vs duty-cycled power
//! across frame rates for both classifiers, reproducing the paper's
//! 21.8 mW → 4.6 mW power-optimization story and locating the duty-cycle
//! crossover.
//!
//! Run: `cargo run --release --example power_sweep`

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::model::weights::load_tbw;
use tinbinn::power::PowerModel;
use tinbinn::runtime::artifacts_dir;
use tinbinn::soc::Board;

fn main() -> tinbinn::Result<()> {
    let dir = artifacts_dir();
    let model = PowerModel::default();

    for task in ["1cat", "10cat"] {
        let np = load_tbw(dir.join(format!("weights_{task}.tbw")), task)?;
        let compiled = compile(&np, InputMode::Direct)?;
        let mut board = Board::new(&compiled);
        let img = vec![128u8; 3072];
        let (_, report) = board.infer(&compiled, &img)?;

        let b = model.continuous(&report);
        let max_fps = 1000.0 / report.ms();
        println!("== {task}: {:.1} ms/frame -> max {max_fps:.1} fps ==", report.ms());
        println!(
            "  continuous: {:.1} mW  [static {:.2} | clock {:.1} | scratchpad {:.2} | datapath {:.2} | dma {:.2} | camera {:.1}]",
            b.total_mw(), b.static_mw, b.clock_mw, b.scratchpad_mw, b.datapath_mw, b.dma_mw, b.camera_mw
        );
        if task == "1cat" {
            println!("  paper anchors: 21.8 mW continuous, 4.6 mW @1 fps");
        }
        println!("  duty-cycled sweep:");
        for fps in [0.2, 0.5, 1.0, 2.0, 5.0, 10.0] {
            let p = model.duty_cycled(&report, fps);
            let bar = "#".repeat((p * 2.0) as usize);
            println!("    {fps:>5.1} fps  {p:>6.2} mW  {bar}");
        }
        let crossover = (0..10_000)
            .map(|i| i as f64 / 100.0)
            .find(|&fps| model.duty_cycled(&report, fps) >= b.total_mw() * 0.99)
            .unwrap_or(max_fps);
        println!("  duty cycling stops paying at ~{crossover:.1} fps\n");
    }
    Ok(())
}
