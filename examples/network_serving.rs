//! Network serving end to end, no artifacts required: bring up the
//! TBNP/1 TCP front-end over two fixture models on two different
//! engines, verify wire scores are bit-exact with the golden oracle,
//! run a closed-loop load burst, and drain with exact accounting.
//!
//! Run: `cargo run --release --example network_serving`
//!
//! This is the in-process twin of
//! `tinbinn serve --listen 127.0.0.1:0` + `tinbinn bench-load`.

use std::collections::HashMap;
use std::sync::Arc;

use tinbinn::coordinator::batcher::BatchPolicy;
use tinbinn::coordinator::gateway::GatewayLane;
use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
use tinbinn::net::{
    parse_mix, run_load, Client, LoadConfig, LoadMode, MonotonicClock, NetServer, ServerConfig,
    Status,
};
use tinbinn::nn::layers::forward;
use tinbinn::testkit::fixtures;

fn main() -> tinbinn::Result<()> {
    // 1. register both paper tasks on different engines (synthetic
    //    trained-like fixtures, so this runs on a bare checkout)
    let (np1, ds1) = fixtures::synthetic_task("1cat")?;
    let (np10, ds10) = fixtures::synthetic_task("10cat")?;
    let mut registry = ModelRegistry::new();
    registry.register(
        ModelSpec { name: "1cat".into(), backend: BackendKind::Bitplane, workers: 2 },
        np1.clone(),
    )?;
    registry.register(
        ModelSpec { name: "10cat".into(), backend: BackendKind::Opt, workers: 2 },
        np10.clone(),
    )?;

    // 2. lanes + the TCP front-end on an ephemeral port
    let policy = BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 4096 };
    let mut lanes = Vec::new();
    for entry in registry.entries() {
        lanes.push(GatewayLane {
            name: entry.spec.name.clone(),
            policy,
            workers: registry.build_pool(entry)?,
        });
    }
    let srv = NetServer::start(
        "127.0.0.1:0",
        lanes,
        ServerConfig::default(),
        Arc::new(MonotonicClock::new()),
    )?;
    let addr = srv.local_addr();
    println!("serving 1cat:bitplane + 10cat:opt on {addr}");

    // 3. one pipelined client: wire scores must equal the golden oracle
    let mut client = Client::connect(addr)?;
    for (name, np, ds) in [("1cat", np1, ds1), ("10cat", np10, ds10)] {
        let imgs: Vec<&[u8]> = (0..4).map(|i| ds.image(i)).collect();
        let resps = client.infer_pipelined(name, &imgs)?;
        for (img, r) in imgs.iter().zip(&resps) {
            assert_eq!(r.status, Status::Ok);
            assert_eq!(r.scores, forward(np, img)?, "{name}: wire != golden");
        }
        println!(
            "{name}: {} frames over TCP, bit-exact with the golden model (first scores {:?})",
            resps.len(),
            resps[0].scores
        );
    }

    // 4. a closed-loop load burst across both models
    let mut images: HashMap<String, Vec<Vec<u8>>> = HashMap::new();
    images.insert("1cat".into(), (0..8).map(|i| ds1.image(i).to_vec()).collect());
    images.insert("10cat".into(), (0..8).map(|i| ds10.image(i).to_vec()).collect());
    let cfg = LoadConfig {
        conns: 2,
        requests: 64,
        mix: parse_mix("1cat:bitplane=0.7,10cat:opt=0.3")?,
        mode: LoadMode::Closed { inflight: 4 },
        deadline_us: None,
        low_frac: 0.0,
        seed: 9,
    };
    let load = run_load(&addr.to_string(), &cfg, &images)?;
    assert_eq!(load.lost, 0, "every request answered");
    assert!(load.conserved());
    println!(
        "load: {} ok / {} rejected / {} expired in {:.2}s -> {:.0} fps",
        load.ok, load.rejected, load.expired, load.wall_s, load.throughput_per_s
    );
    for m in &load.models {
        println!(
            "  {:6}: p50 {}us p99 {}us, {:.0} fps",
            m.name,
            m.latency.p50_us(),
            m.latency.p99_us(),
            m.throughput_per_s
        );
    }

    // 5. graceful drain: the ledger must balance exactly
    let report = srv.shutdown()?;
    assert!(report.conserved(), "gateway accounting violated");
    println!(
        "drained: {} submitted == {} completed + {} rejected + {} expired (conserved)",
        report.submitted, report.completed, report.rejected, report.expired
    );
    Ok(())
}
