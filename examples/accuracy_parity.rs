//! E2 — float-vs-fixed accuracy parity (the paper's central numeric
//! claim: converting to 8b activations and fixed-point arithmetic costs
//! ZERO accuracy; "the error can be attributed entirely to training").
//!
//! Sweeps the synthetic test set through the float-semantics reference
//! and the fixed-point golden model, reporting per-task error rates, the
//! prediction-agreement rate, and the score divergence distribution.
//!
//! Run: `cargo run --release --example accuracy_parity [n]`

use tinbinn::data::tbd::load_tbd;
use tinbinn::model::weights::load_tbw;
use tinbinn::nn::floatref::forward_float;
use tinbinn::nn::layers::{classify, forward};
use tinbinn::runtime::artifacts_dir;

fn main() -> tinbinn::Result<()> {
    let dir = artifacts_dir();
    let limit: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(300);

    for task in ["10cat", "1cat"] {
        let np = load_tbw(dir.join(format!("weights_{task}.tbw")), task)?;
        let ds = load_tbd(dir.join(format!("data_{task}_test.tbd")))?;
        let n = ds.len().min(limit);

        let mut float_ok = 0;
        let mut fixed_ok = 0;
        let mut agree = 0;
        let mut max_rel_div: f64 = 0.0;
        let mut sum_rel_div: f64 = 0.0;

        for i in 0..n {
            let img = ds.image(i);
            let want = ds.labels[i] as usize;
            let fx = forward(&np, img)?;
            let fl = forward_float(&np, img)?;
            let pf = classify(&fx);
            let pl = if fl.len() == 1 {
                (fl[0] > 0.0) as usize
            } else {
                fl.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            float_ok += (pl == want) as usize;
            fixed_ok += (pf == want) as usize;
            agree += (pf == pl) as usize;
            for (a, b) in fx.iter().zip(&fl) {
                let rel = (*a as f64 - *b as f64).abs() / (b.abs() as f64).max(256.0);
                max_rel_div = max_rel_div.max(rel);
                sum_rel_div += rel / fx.len() as f64;
            }
        }

        println!("== {task} (n={n}) ==");
        println!(
            "  float error {:.2}%   fixed error {:.2}%   |Δ| = {:.2}pp   (paper: Δ = 0.0pp)",
            100.0 * (1.0 - float_ok as f64 / n as f64),
            100.0 * (1.0 - fixed_ok as f64 / n as f64),
            100.0 * ((float_ok as f64 - fixed_ok as f64) / n as f64).abs()
        );
        println!(
            "  prediction agreement {:.1}%   score divergence: mean {:.4}, max {:.4} (relative)",
            100.0 * agree as f64 / n as f64,
            sum_rel_div / n as f64,
            max_rel_div
        );
    }
    println!("\nconclusion: quantization moves scores by rounding noise only;");
    println!("any residual error difference is training, not precision — as the paper claims.");
    Ok(())
}
