//! E11 — the END-TO-END driver: the embedded person detector of Fig. 1,
//! camera to decision, every stage exercised:
//!
//!   dataset image → synthetic VGA sensor frame (640x480 RGB565)
//!   → hardware 16x downscaler (40x30 RGBA) → DMA into scratchpad
//!   → firmware de-interleave + centre crop → binarized CNN on the
//!   overlay (cycle-accurate) → SVM scores → detection
//!
//! Reports detection accuracy over the stream, per-frame latency at
//! 24 MHz, sustained fps, and the power model's two operating points —
//! the full set of §II claims on one real workload.
//!
//! Run: `make artifacts && cargo run --release --example person_detector`

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::data::tbd::load_tbd;
use tinbinn::model::weights::load_tbw;
use tinbinn::nn::bitplane::BitplaneModel;
use tinbinn::nn::opt::{OptModel, Scratch};
use tinbinn::power::PowerModel;
use tinbinn::runtime::artifacts_dir;
use tinbinn::soc::{cycles_to_ms, Board, Camera};

fn main() -> tinbinn::Result<()> {
    let dir = artifacts_dir();
    let np = load_tbw(dir.join("weights_1cat.tbw"), "1cat")?;
    let ds = load_tbd(dir.join("data_1cat_test.tbd"))?;
    let n_frames = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(40usize)
        .min(ds.len());

    // camera-mode program: the schedule crops 32x32 out of the padded
    // 40x30 frame exactly like the MDP firmware
    let compiled = compile(&np, InputMode::Camera)?;
    let mut board = Board::new(&compiled);
    let camera = Camera::new(7);
    let power = PowerModel::default();

    println!("TinBiNN person detector — {} frames through the full camera path", n_frames);
    let mut correct = 0usize;
    let mut total_cycles = 0u64;
    let mut last_report = None;
    let wall0 = std::time::Instant::now();

    for i in 0..n_frames {
        // 1. sensor: upsample the 32x32 dataset image to a VGA RGB565 frame
        let frame = camera.frame_from_image(ds.image(i), 32, 32);
        // 2. gateware downscaler -> 40x30 RGBA
        let rgba = camera.downscale(&frame);
        // 3..6. DMA + de-interleave + crop + CNN on the overlay
        let (scores, report) = board.infer(&compiled, &rgba)?;
        let detected = scores[0] > 0;
        let truth = ds.labels[i] == 1;
        correct += (detected == truth) as usize;
        total_cycles += report.total_cycles;
        if i < 5 {
            println!(
                "  frame {i}: score {:>7}  detected={detected:5}  truth={truth:5}  {:.1} ms on-device",
                scores[0],
                report.ms()
            );
        }
        last_report = Some(report);
    }

    let ms_per_frame = cycles_to_ms(total_cycles) / n_frames as f64;
    let acc = 100.0 * correct as f64 / n_frames as f64;
    println!("\nresults over {n_frames} frames:");
    println!("  detection accuracy (through camera path): {acc:.1}%  ({correct}/{n_frames})");
    println!(
        "  on-device latency: {:.1} ms/frame @24 MHz  -> {:.1} fps sustained (paper: 195 ms)",
        ms_per_frame,
        1000.0 / ms_per_frame
    );
    if let Some(r) = &last_report {
        let cont = power.continuous(r).total_mw();
        let duty = power.duty_cycled(r, 1.0);
        println!(
            "  power: {:.1} mW continuous (paper 21.8), {:.1} mW duty-cycled @1 fps (paper 4.6)",
            cont, duty
        );
    }
    println!("  simulator wall-clock: {:.2} s for {n_frames} frames", wall0.elapsed().as_secs_f64());

    // The serving-side fast path on the same stream: nn::opt consumes
    // the dataset images directly (no camera loss), showing what the
    // host can sustain when frames bypass the cycle-accurate simulator.
    let engine = OptModel::new(&np)?;
    let mut scratch = Scratch::new();
    let t0 = std::time::Instant::now();
    let mut host_correct = 0usize;
    let mut host_scores = Vec::with_capacity(n_frames);
    for i in 0..n_frames {
        let scores = engine.forward(ds.image(i), &mut scratch)?;
        let detected = scores[0] > 0;
        host_correct += (detected == (ds.labels[i] == 1)) as usize;
        host_scores.push(scores[0]);
    }
    let host_s = t0.elapsed().as_secs_f64();
    println!(
        "  host fast path (nn::opt): {:.0} fps wall-clock, accuracy {:.1}% ({} frames)",
        n_frames as f64 / host_s.max(1e-9),
        100.0 * host_correct as f64 / n_frames as f64,
        n_frames
    );

    // The popcount datapath on the same stream: the bit-plane engine is
    // the fastest single-image CPU path and must agree bit-for-bit.
    let bp_engine = BitplaneModel::new(&np)?;
    let mut bp_scratch = tinbinn::nn::bitplane::Scratch::new();
    let t0 = std::time::Instant::now();
    let mut bp_correct = 0usize;
    for i in 0..n_frames {
        let scores = bp_engine.forward(ds.image(i), &mut bp_scratch)?;
        let detected = scores[0] > 0;
        bp_correct += (detected == (ds.labels[i] == 1)) as usize;
        assert_eq!(
            scores[0], host_scores[i],
            "bitplane engine disagrees with nn::opt on frame {i}"
        );
    }
    let bp_s = t0.elapsed().as_secs_f64();
    println!(
        "  host popcount path (nn::bitplane): {:.0} fps wall-clock, accuracy {:.1}% ({} frames)",
        n_frames as f64 / bp_s.max(1e-9),
        100.0 * bp_correct as f64 / n_frames as f64,
        n_frames
    );

    // The serving front door on the same stream: the multi-model
    // gateway runs the detector as two named models on two distinct
    // engines at once (the popcount hot path and the bit-packed
    // engine), with per-model accounting — and both lanes must agree
    // bit-for-bit with the serial fast path above.
    use tinbinn::coordinator::batcher::BatchPolicy;
    use tinbinn::coordinator::gateway::{serve_gateway, GatewayConfig, GatewayLane, GatewayRequest};
    use tinbinn::coordinator::registry::AnyBackend;
    use tinbinn::coordinator::backend::{BitplaneBackend, OptBackend};
    let policy = BatchPolicy { max_batch: 8, max_wait_us: 200, queue_cap: 1024 };
    let lanes = vec![
        GatewayLane {
            name: "det-bitplane".to_string(),
            policy,
            workers: (0..2)
                .map(|_| Ok(AnyBackend::Bitplane(BitplaneBackend::new(&np)?)))
                .collect::<tinbinn::Result<Vec<_>>>()?,
        },
        GatewayLane {
            name: "det-opt".to_string(),
            policy,
            workers: (0..2)
                .map(|_| Ok(AnyBackend::Opt(OptBackend::new(&np)?)))
                .collect::<tinbinn::Result<Vec<_>>>()?,
        },
    ];
    let requests: Vec<GatewayRequest> = (0..2 * n_frames)
        .map(|i| {
            let model = if i % 2 == 0 { "det-bitplane" } else { "det-opt" };
            GatewayRequest::new(i as u64, model, ds.image((i / 2) % ds.len()).to_vec())
        })
        .collect();
    let (report, _lanes) = serve_gateway(requests, lanes, &GatewayConfig { collect_scores: true, drain: None })?;
    assert!(report.conserved(), "gateway accounting violated");
    for m in &report.models {
        for (id, scores) in &m.scores {
            let frame_i = *id as usize / 2; // requests interleave the two lanes
            assert_eq!(
                scores[0], host_scores[frame_i],
                "gateway lane {} disagrees with the serial fast path on frame {frame_i}",
                m.name
            );
        }
    }
    println!("\n  serving gateway (2 models x 2 workers, bit-exact with the fast path):");
    for m in &report.models {
        println!(
            "    {:12} on {:12}: {} frames, mean batch {:.2}, p99 {}us, {:.0} fps",
            m.name, m.backend, m.completed, m.mean_batch, m.latency.p99_us, m.throughput_per_s
        );
    }
    println!("    fleet: {:.0} fps over {} frames", report.throughput_per_s, report.completed);

    // The native training loop: BinaryConnect-train the micro 1-category
    // detector from scratch on the seeded synthetic task, export TBW1,
    // run the cross-engine acceptance gate, and serve the freshly
    // trained model through the same gateway under a new name — the full
    // train -> TBW1 -> all-engines story with no python in the loop.
    use tinbinn::coordinator::registry::{BackendKind, ModelRegistry, ModelSpec};
    use tinbinn::model::zoo::micro_1cat;
    use tinbinn::testkit::fixtures;
    use tinbinn::train::{self, TrainConfig};

    println!("\n  native training (micro 1-cat detector, synthetic task):");
    let micro = micro_1cat();
    let (_, train_ds) = fixtures::eval_set(&micro, 32)?;
    let cfg = TrainConfig { epochs: 80, ..TrainConfig::default() };
    let t0 = std::time::Instant::now();
    let outcome = train::fit(&micro, &train_ds, &cfg)?;
    println!(
        "    trained {} epochs in {:.1}s -> best integer accuracy {:.1}% (epoch {})",
        outcome.epochs_run,
        t0.elapsed().as_secs_f64(),
        100.0 * outcome.best_acc,
        outcome.best_epoch
    );
    let gate = train::export::acceptance_gate(&outcome.params, &train_ds, 4)?;
    println!(
        "    gate: golden/opt/bitplane/overlay bit-exact on {} images, accuracy {:.1}%",
        gate.n_diff,
        100.0 * gate.accuracy
    );

    let mut registry = ModelRegistry::new();
    registry.register(
        ModelSpec { name: "micro-trained".into(), backend: BackendKind::Bitplane, workers: 2 },
        outcome.params.clone(),
    )?;
    let entry = registry.get("micro-trained").expect("just registered");
    let lanes = vec![GatewayLane {
        name: "micro-trained".to_string(),
        policy,
        workers: registry.build_pool(entry)?,
    }];
    let requests: Vec<GatewayRequest> = (0..train_ds.len())
        .map(|i| GatewayRequest::new(i as u64, "micro-trained", train_ds.image(i).to_vec()))
        .collect();
    let (tr_report, _lanes) =
        serve_gateway(requests, lanes, &GatewayConfig { collect_scores: true, drain: None })?;
    assert!(tr_report.conserved(), "gateway accounting violated");
    for m in &tr_report.models {
        for (id, scores) in &m.scores {
            let img = train_ds.image(*id as usize);
            let want = tinbinn::nn::layers::forward(&outcome.params, img)?;
            assert_eq!(
                scores, &want,
                "freshly trained model diverged in the gateway on request {id}"
            );
        }
    }
    println!(
        "    served the freshly trained model: {} frames, {:.0} fps, bit-exact with golden",
        tr_report.completed, tr_report.throughput_per_s
    );
    Ok(())
}
