//! Design-space ablation: what the overlay's two key design choices buy.
//!
//! 1. The Fig. 2 conv accelerator — runtime with vs without (scalar
//!    RV32IM loops measured on the ISS), at their LUT costs: the paper's
//!    performance-per-LUT argument.
//! 2. Conv-unit parallelism — the resource/runtime trade at 1/2/4
//!    parallel convolutions (the paper shipped 2).
//!
//! Run: `cargo run --release --example overlay_explorer`

use tinbinn::compiler::lower::{compile, InputMode};
use tinbinn::isa::baseline::{measure_rates, scalar_net_cycles};
use tinbinn::model::weights::load_tbw;
use tinbinn::resources::{estimate, OverlayConfig};
use tinbinn::runtime::artifacts_dir;
use tinbinn::soc::Board;

fn main() -> tinbinn::Result<()> {
    let dir = artifacts_dir();
    let np = load_tbw(dir.join("weights_10cat.tbw"), "10cat")?;

    // measured overlay runtime
    let compiled = compile(&np, InputMode::Direct)?;
    let mut board = Board::new(&compiled);
    let img = vec![128u8; 3072];
    let (_, report) = board.infer(&compiled, &img)?;

    // measured scalar baseline
    let rates = measure_rates()?;
    let (sc_conv, sc_dense, sc_misc) = scalar_net_cycles(&np.net, &rates);
    let scalar_ms = (sc_conv + sc_dense + sc_misc) as f64 / 24e3;

    println!("== ablation 1: does the accelerator pay for its LUTs? (10cat) ==");
    let with = estimate(&OverlayConfig::paper());
    let without = estimate(&OverlayConfig::scalar_only());
    println!(
        "  scalar ORCA   : {:>7.0} ms/frame   {:>5} LUTs",
        scalar_ms,
        without.total_luts()
    );
    println!(
        "  TinBiNN overlay: {:>6.1} ms/frame   {:>5} LUTs",
        report.ms(),
        with.total_luts()
    );
    let speedup = scalar_ms / report.ms();
    let lut_ratio = with.total_luts() as f64 / without.total_luts() as f64;
    println!(
        "  -> {speedup:.0}x faster for {:.2}x the LUTs = {:.0}x performance/LUT (paper's core argument)",
        lut_ratio,
        speedup / lut_ratio
    );

    println!("\n== ablation 2: conv-unit parallelism (resource model) ==");
    for par in [1u32, 2, 4, 8] {
        let cfg = OverlayConfig { conv_parallelism: par, ..OverlayConfig::paper() };
        let r = estimate(&cfg);
        // conv body scales ~1/par until the read ports saturate at 4
        let eff_par = par.min(4) as f64;
        let conv_cycles: u64 = report
            .per_layer
            .iter()
            .filter(|l| l.name == "conv3x3")
            .map(|l| l.cycles)
            .sum();
        let rest = report.total_cycles - conv_cycles;
        let est_ms = (rest as f64 + conv_cycles as f64 * 2.0 / eff_par) / 24e3;
        let fits = if r.fits() { "fits" } else { "DOES NOT FIT" };
        println!(
            "  {par}x parallel: {:>5} LUTs ({})  est. {est_ms:>6.1} ms/frame{}",
            r.total_luts(),
            fits,
            if par == 2 { "   <- paper's choice" } else { "" }
        );
    }
    println!("\n(2x is the sweet spot: 4x saturates the 2R+1W scratchpad ports");
    println!(" and 8x no longer fits the UP5K — the paper's design point.)");
    Ok(())
}
